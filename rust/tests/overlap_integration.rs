//! Concurrent-runtime integration: the overlapped HOP-B pipeline must
//! beat lockstep wall-clock under a modeled All-to-All link while
//! generating bit-identical tokens, and a dead rank must surface as an
//! error instead of hanging the coordinator.
//!
//! The link is calibrated against this machine's measured per-row
//! attention time: overlap can only hide `min(compute, link)` per
//! chunk, so the modeled per-row transfer is set to ~1.5x the per-row
//! compute — slow enough that lockstep exposes it, fast enough that
//! the pipeline hides a large fraction behind the next row's compute.

mod common;

use std::time::Duration;

use helix::config::Layout;
use helix::engine::{ClusterConfig, ClusterError, CommModel};

use crate::common::cluster_or_skip;

const MODEL: &str = "tiny_gqa";
const WARMUP: usize = 2;
const STEPS: usize = 14;

fn layout() -> Layout {
    Layout::helix(2, 2, 4, 1)
}

/// Per-row A2A payload for `tiny_gqa` under kvp2 x tpa2:
/// (q_heads/tpa) * head_size * 4 bytes * (kvp-1)/kvp = 4*32*4/2.
const ROW_BYTES: f64 = 256.0;

struct Run {
    /// Every step's sampled next-token vector (greedy, so this is the
    /// full decode trajectory).
    tokens: Vec<Vec<i32>>,
    /// Summed step wall time over the post-warmup window.
    wall: Duration,
    /// Link time the ranks actually waited for (post-warmup).
    exposed: Duration,
    /// Summed modeled link time, overlap ignored (post-warmup).
    total: Duration,
    /// Attention-phase time (post-warmup) — calibration input.
    attn: Duration,
}

fn decode_run(hopb: bool, a2a: Option<CommModel>) -> Option<Run> {
    let mut cc = ClusterConfig::new(MODEL, layout());
    cc.hopb = hopb;
    cc.a2a_comm = a2a;
    let mut cluster = cluster_or_skip(cc)?;
    for s in 0..cluster.batch() {
        cluster.open_slot(s).unwrap();
    }
    let mut tokens: Vec<i32> = (0..cluster.batch() as i32).map(|i| i + 5)
        .collect();
    let mut run = Run {
        tokens: Vec::new(),
        wall: Duration::ZERO,
        exposed: Duration::ZERO,
        total: Duration::ZERO,
        attn: Duration::ZERO,
    };
    for step in 0..WARMUP + STEPS {
        let (next, sm) = cluster.decode_step(&tokens).expect("step");
        if step >= WARMUP {
            run.wall += sm.total;
            run.exposed += sm.comm_exposed;
            run.total += sm.comm_total;
            run.attn += sm.attn;
        }
        run.tokens.push(next.clone());
        tokens = next;
    }
    cluster.shutdown();
    Some(run)
}

/// The tentpole assertion: same tokens, same modeled bytes, less wall
/// clock and a lower exposed-comm fraction with the pipeline on.
#[test]
fn overlapped_hopb_beats_lockstep_with_identical_tokens() {
    // Calibration pass: HOP-B with no modeled comm measures the real
    // per-row attention time (4 layers x 4 batch rows per step).
    let Some(free) = decode_run(true, None) else { return };
    assert_eq!(free.total, Duration::ZERO,
               "no comm model, yet link time was charged");
    assert_eq!(free.exposed, Duration::ZERO,
               "no comm model, yet ranks waited on transfers");
    let chunks = (STEPS * 4 * 4) as f64;
    let chunk_s = (free.attn.as_secs_f64() / chunks).clamp(60e-6, 5e-3);
    let link = CommModel {
        latency_s: 0.0,
        bw_bytes_per_s: ROW_BYTES / (1.5 * chunk_s),
        scale: 1.0,
    };

    let off = decode_run(false, Some(link)).unwrap();
    let on = decode_run(true, Some(link)).unwrap();

    // Exactness: the schedule (lockstep vs pipelined, modeled link vs
    // none) must never change the numerics.
    assert_eq!(free.tokens, off.tokens,
               "modeled comm changed lockstep tokens");
    assert_eq!(off.tokens, on.tokens,
               "HOP-B pipelining changed the decoded tokens");

    // Same layout, same bytes: the charged link time is schedule-
    // independent (one B-row transfer vs B row transfers per layer).
    let (t_off, t_on) = (off.total.as_secs_f64(), on.total.as_secs_f64());
    assert!(t_off > 0.0, "scaled link charged no time");
    assert!((t_off - t_on).abs() < 0.01 * t_off,
            "modeled link totals diverged: off {t_off:.6}s vs on {t_on:.6}s");

    // Accounting sanity: a step cannot wait longer than the link was
    // busy (small slop for per-chunk rounding).
    assert!(off.exposed.as_secs_f64() <= t_off * 1.05 + 1e-3,
            "exposed {:?} exceeds modeled total {:?}", off.exposed,
            off.total);

    // The point of the PR: the pipeline hides link time behind the next
    // row's attention, so the exposed fraction drops and the step gets
    // faster. Lockstep exposes ~everything (ranks idle during the
    // transfer); the pipeline must hide >10% of it.
    let (e_off, e_on) = (off.exposed.as_secs_f64(), on.exposed.as_secs_f64());
    assert!(e_off > 0.5 * t_off,
            "lockstep should expose most of the link time: exposed \
             {e_off:.6}s of {t_off:.6}s");
    assert!(e_on < 0.9 * e_off,
            "HOP-B overlap hid too little: exposed {e_on:.6}s (on) vs \
             {e_off:.6}s (off), link {:.1}us/row", 1.5 * chunk_s * 1e6);
    assert!(on.wall < off.wall,
            "overlapped step not faster: {:?} (on) vs {:?} (off)",
            on.wall, off.wall);
    println!("overlap: exposed {:.3}ms -> {:.3}ms (total {:.3}ms), wall \
              {:.3}ms -> {:.3}ms over {STEPS} steps",
             e_off * 1e3, e_on * 1e3, t_off * 1e3,
             off.wall.as_secs_f64() * 1e3, on.wall.as_secs_f64() * 1e3);
}

/// Satellite: hang-proofing. A rank thread that dies mid-run must turn
/// into a coordinator error within the recv timeout, not a deadlock.
#[test]
fn crashed_rank_errors_instead_of_hanging() {
    let mut cc = ClusterConfig::new(MODEL, layout());
    cc.recv_timeout = Duration::from_millis(500);
    let Some(mut cluster) = cluster_or_skip(cc) else { return };
    for s in 0..cluster.batch() {
        cluster.open_slot(s).unwrap();
    }
    let tokens: Vec<i32> = (0..cluster.batch() as i32).map(|i| i + 5)
        .collect();
    cluster.decode_step(&tokens).expect("healthy pool decodes");

    cluster.inject_crash(1).expect("crash command delivered");
    let start = std::time::Instant::now();
    let err = cluster.decode_step(&tokens)
        .expect_err("decode through a dead rank must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("rank"),
            "error should point at the rank pool: {msg}");
    assert!(start.elapsed() < Duration::from_secs(10),
            "dead-rank detection took {:?} — hang-proofing failed",
            start.elapsed());

    // The pool is unusable but must stay shut-downable.
    cluster.shutdown();
}

/// Satellite: a crash injected *mid-flight* — between the HOP-B
/// dispatch of `decode_step_begin` and the logits collection of
/// `decode_step_finish` — must not corrupt the in-flight step (ranks
/// drain already-queued work before dying), and the *next* collective
/// must surface a typed fatal error instead of hanging.
#[test]
fn crash_mid_flight_hopb_errors_instead_of_hanging() {
    let mut cc = ClusterConfig::new(MODEL, layout());
    cc.hopb = true;
    cc.verify = true; // oracle checks the mid-flight step's numerics
    cc.recv_timeout = Duration::from_millis(500);
    let Some(mut cluster) = cluster_or_skip(cc) else { return };
    for s in 0..cluster.batch() {
        cluster.open_slot(s).unwrap();
    }
    let tokens: Vec<i32> = (0..cluster.batch() as i32).map(|i| i + 5)
        .collect();
    cluster.decode_step(&tokens).expect("healthy pool decodes");

    // Crash lands behind the in-flight step in rank 1's command queue:
    // the step it already started must complete, numerically intact.
    let pending = cluster.decode_step_begin(&tokens)
        .expect("step dispatch on a healthy pool");
    cluster.inject_crash(1).expect("mid-flight crash is legal");
    let (_, sm) = cluster.decode_step_finish(pending)
        .expect("the dispatched step drains cleanly past the crash");
    let d = sm.max_ref_diff.expect("verify mirror should have run");
    assert!(d < 1e-3, "crash command corrupted an in-flight step \
                       (drift {d:.3e} from the reference)");

    // The next step needs rank 1 and must fail typed and timely.
    let start = std::time::Instant::now();
    let err = cluster.decode_step(&tokens)
        .expect_err("decode through a dead rank must fail");
    let ce = ClusterError::find(&err)
        .expect("dead-rank failure should carry a typed ClusterError");
    assert!(ce.is_fatal(),
            "a dead rank is a fatal pool error, got {ce}");
    assert!(start.elapsed() < Duration::from_secs(10),
            "dead-rank detection took {:?} — hang-proofing failed",
            start.elapsed());
    cluster.shutdown();
}

/// Satellite: the survivable fault path still works alongside the new
/// timeout plumbing — a rank that *reports* failure keeps serving.
#[test]
fn injected_fault_is_survivable() {
    let mut cc = ClusterConfig::new(MODEL, layout());
    cc.recv_timeout = Duration::from_millis(2_000);
    let Some(mut cluster) = cluster_or_skip(cc) else { return };
    for s in 0..cluster.batch() {
        cluster.open_slot(s).unwrap();
    }
    let tokens: Vec<i32> = (0..cluster.batch() as i32).map(|i| i + 5)
        .collect();
    let a = cluster.decode_step(&tokens).expect("step").0;
    let e = cluster.inject_fault(2, "synthetic").expect("fault round-trip");
    assert!(e.contains("synthetic"), "fault message lost: {e}");
    let b = cluster.decode_step(&tokens).expect("pool survives a fault").0;
    assert_eq!(a.len(), b.len());
    cluster.shutdown();
}
