//! Offload/restore exactness: suspending a session to the host-tier
//! store and resuming it — even into a *different* batch slot — must
//! leave the decoded token stream bit-identical to an uninterrupted
//! run, across KVP widths and native worker counts. The per-rank blob
//! format round-trips logical order, so storage layout (page tables,
//! pool order) is free to differ before and after the trip.
//!
//! One #[test] on purpose: the matrix mutates `HELIX_NATIVE_THREADS`,
//! which is process-global state — parallel tests in this binary would
//! race it (same convention as tests/concurrency_exactness.rs).

mod common;

use std::time::{Duration, Instant};

use helix::config::{KvDtype, Layout};
use helix::engine::{ClusterConfig, ClusterError};
use helix::runtime::BackendKind;

use crate::common::cluster_or_skip;

const PRE: usize = 6; // decode steps before the evict/restore trip
const POST: usize = 6; // decode steps after it

/// `verify` keeps the unsharded oracle checking every step; it must be
/// off for quantized layouts (the mirror is f32-only — the cluster
/// refuses the combination).
fn boot_cluster(model: &str, layout: Layout, verify: bool)
                -> Option<helix::engine::HelixCluster> {
    let mut cc = ClusterConfig::new(model, layout);
    cc.verify = verify;
    let mut cluster = cluster_or_skip(cc)?;
    for s in 0..cluster.batch() {
        cluster.open_slot(s).unwrap();
    }
    Some(cluster)
}

fn step(cluster: &mut helix::engine::HelixCluster, tokens: &[i32])
        -> Vec<i32> {
    let (next, m) = cluster.decode_step(tokens).expect("decode step");
    if let Some(d) = m.max_ref_diff {
        assert!(d < 1e-3, "engine drifted {d:.3e} from the reference");
    }
    next
}

/// Uninterrupted run: PRE + POST steps, sessions never leave their
/// slots. The stream is indexed [step][session].
fn reference(model: &str, layout: Layout, verify: bool)
             -> Option<Vec<Vec<i32>>> {
    let mut cluster = boot_cluster(model, layout, verify)?;
    let mut tokens: Vec<i32> =
        (0..cluster.batch() as i32).map(|i| i + 5).collect();
    let mut stream = Vec::with_capacity(PRE + POST);
    for _ in 0..PRE + POST {
        let next = step(&mut cluster, &tokens);
        stream.push(next.clone());
        tokens = next;
    }
    cluster.shutdown();
    Some(stream)
}

/// Churned run: after PRE steps, sessions in slots 1 and 2 are evicted
/// to the host tier and restored *swapped* (session from slot 1 comes
/// back in slot 2 and vice versa), then decode POST more steps. The
/// returned stream is re-indexed by session so it must equal the
/// reference bit for bit.
fn churned(model: &str, layout: Layout, verify: bool)
           -> Option<Vec<Vec<i32>>> {
    let mut cluster = boot_cluster(model, layout, verify)?;
    let n = cluster.n();
    let mut tokens: Vec<i32> =
        (0..cluster.batch() as i32).map(|i| i + 5).collect();
    let mut stream = Vec::with_capacity(PRE + POST);
    for _ in 0..PRE {
        let next = step(&mut cluster, &tokens);
        stream.push(next.clone());
        tokens = next;
    }

    // Suspend two sessions: every rank streams its own shard to the
    // store, so the blob count is sessions x ranks.
    let snap1 = cluster.evict_slot(1, 101).expect("evict slot 1");
    let snap2 = cluster.evict_slot(2, 102).expect("evict slot 2");
    let st = cluster.store_stats();
    assert!(st.bytes_in > 0, "eviction streamed no KV bytes");
    assert_eq!(st.blobs, 2 * n,
               "expected one host-tier blob per (session, rank)");

    // Resume them swapped: restore is slot-agnostic because blobs are
    // serialized in logical token order, not storage order.
    cluster.restore_slot(1, &snap2).expect("restore 102 into slot 1");
    cluster.restore_slot(2, &snap1).expect("restore 101 into slot 2");
    let st = cluster.store_stats();
    assert_eq!(st.blobs, 0, "restore must drain the store");
    assert!(st.bytes_out >= st.bytes_in);

    // Slot r now holds the session that generated tokens[perm[r]].
    tokens.swap(1, 2);
    for _ in 0..POST {
        let next = step(&mut cluster, &tokens);
        let mut by_session = next.clone();
        by_session.swap(1, 2); // undo the slot permutation
        stream.push(by_session);
        tokens = next;
    }
    cluster.shutdown();
    Some(stream)
}

/// Hang-proofing: a rank that dies mid-run turns the next evict
/// collective into a timely coordinator error, never a deadlock.
fn crash_during_evict_errors() {
    let mut cc = ClusterConfig::new("tiny_gqa", Layout::helix(2, 2, 4, 1));
    cc.recv_timeout = Duration::from_millis(500);
    let Some(mut cluster) = cluster_or_skip(cc) else { return };
    for s in 0..cluster.batch() {
        cluster.open_slot(s).unwrap();
    }
    let tokens: Vec<i32> = (0..cluster.batch() as i32).map(|i| i + 5)
        .collect();
    cluster.decode_step(&tokens).expect("healthy pool decodes");

    cluster.inject_crash(1).expect("crash command delivered");
    let start = Instant::now();
    let err = cluster.evict_slot(0, 7)
        .expect_err("evict through a dead rank must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("rank"),
            "error should point at the rank pool: {msg}");
    assert!(start.elapsed() < Duration::from_secs(10),
            "dead-rank detection took {:?} — hang-proofing failed",
            start.elapsed());
    cluster.shutdown();
}

/// Hang-proofing, restore edition: a rank that dies *between* evict
/// and restore turns the restore collective into a typed, timely
/// coordinator error — half-consumed blobs and all — never a hang.
fn crash_during_restore_errors() {
    let mut cc = ClusterConfig::new("tiny_gqa", Layout::helix(2, 2, 4, 1));
    cc.recv_timeout = Duration::from_millis(500);
    let Some(mut cluster) = cluster_or_skip(cc) else { return };
    for s in 0..cluster.batch() {
        cluster.open_slot(s).unwrap();
    }
    let tokens: Vec<i32> = (0..cluster.batch() as i32).map(|i| i + 5)
        .collect();
    cluster.decode_step(&tokens).expect("healthy pool decodes");

    let snap = cluster.evict_slot(1, 11).expect("evict slot 1");
    cluster.inject_crash(2).expect("crash command delivered");
    let start = Instant::now();
    let err = cluster.restore_slot(1, &snap)
        .expect_err("restore through a dead rank must fail");
    let ce = ClusterError::find(&err)
        .expect("restore failure should carry a typed ClusterError");
    assert!(ce.is_fatal(),
            "a dead rank is a fatal pool error, got {ce}");
    assert!(start.elapsed() < Duration::from_secs(10),
            "dead-rank detection took {:?} — hang-proofing failed",
            start.elapsed());
    cluster.shutdown();
}

#[test]
fn offload_restore_is_bit_identical_across_kvp_and_threads() {
    // kvp x tpa sweeps the attention grid while n stays 4; the blob
    // format has to reassemble the same logical KV from 1, 2, or 4
    // round-robin shards.
    let layouts = [Layout::helix(1, 4, 4, 1),
                   Layout::helix(2, 2, 4, 1),
                   Layout::helix(4, 1, 4, 1)];
    for layout in layouts {
        std::env::set_var("HELIX_NATIVE_THREADS", "1");
        let Some(want) = reference("tiny_gqa", layout, true) else {
            std::env::remove_var("HELIX_NATIVE_THREADS");
            return; // pjrt-without-artifacts environment
        };
        for threads in ["1", "4"] {
            std::env::set_var("HELIX_NATIVE_THREADS", threads);
            let Some(got) = churned("tiny_gqa", layout, true) else {
                std::env::remove_var("HELIX_NATIVE_THREADS");
                return;
            };
            assert_eq!(want, got,
                       "offload round-trip changed tokens: layout {} \
                        threads {threads}", layout.key());
        }
    }

    // Quantized KV tiers (native-only; the verify mirror is f32-only,
    // so these runs compare quantized-vs-quantized). The dtype-tagged
    // blobs carry raw codes + scales, so a restore — even into swapped
    // slots — reproduces the quantized in-device state bit for bit and
    // the churned stream must equal the uninterrupted one exactly
    // *within* each dtype. No cross-dtype comparison: quantization is
    // allowed to change tokens relative to f32.
    if BackendKind::native_available() {
        for kv_dtype in [KvDtype::F16, KvDtype::Int8] {
            let layout = Layout { kv_dtype, ..Layout::helix(2, 2, 4, 1) };
            std::env::set_var("HELIX_NATIVE_THREADS", "1");
            let want = reference("tiny_gqa", layout, false)
                .expect("native backend never skips");
            for threads in ["1", "4"] {
                std::env::set_var("HELIX_NATIVE_THREADS", threads);
                let got = churned("tiny_gqa", layout, false)
                    .expect("native backend never skips");
                assert_eq!(want, got,
                           "quantized offload round-trip changed tokens: \
                            kv_dtype {} threads {threads}",
                           kv_dtype.name());
            }
        }
    }
    std::env::remove_var("HELIX_NATIVE_THREADS");

    crash_during_evict_errors();
    crash_during_restore_errors();
}
