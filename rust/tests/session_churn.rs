//! Churn-aware admission: a multi-turn session population whose total
//! KV demand oversubscribes the resident budget several times over must
//! still complete in full — idle sessions are evicted to the host-tier
//! session store, restored when they wake, and the generated tokens are
//! bit-identical to an uncontended run. The offload path streams KV as
//! per-KVP-rank blobs: the coordinator never sees the bytes.

mod common;

use std::collections::HashMap;

use helix::config::Layout;
use helix::engine::ClusterConfig;
use helix::serve::{Server, Workload};

use crate::common::cluster_or_skip;

const MODEL: &str = "tiny_gqa";

fn layout() -> Layout {
    Layout::helix(2, 2, 4, 1)
}

/// The evict/restore byte streams are per-rank: every rank parks its
/// own shard blob in the session store, and the coordinator-side
/// snapshot carries identity + logical length only (zero KV bytes in a
/// serving configuration).
#[test]
fn offload_streams_bypass_the_coordinator() {
    let cc = ClusterConfig::new(MODEL, layout());
    let Some(mut cluster) = cluster_or_skip(cc) else { return };
    let b = cluster.batch();
    let n = cluster.n();
    cluster.open_slot(0).unwrap();

    // Reference: the same session decoded without the host-tier trip.
    let cc = ClusterConfig::new(MODEL, layout());
    let Some(mut flat) = cluster_or_skip(cc) else { return };
    flat.open_slot(0).unwrap();
    let mut ref_stream = Vec::new();
    let mut tokens = vec![9i32; b];
    for _ in 0..8 {
        let (next, _) = flat.decode_step(&tokens).unwrap();
        ref_stream.push(next[0]);
        tokens = next;
    }
    flat.shutdown();

    let mut tokens = vec![9i32; b];
    for _ in 0..5 {
        let (next, _) = cluster.decode_step(&tokens).unwrap();
        assert_eq!(next[0], ref_stream.remove(0));
        tokens = next;
    }
    let snap = cluster.evict_slot(0, 77).unwrap();
    assert_eq!(snap.coordinator_kv_bytes(), 0,
               "offload must not gather KV through the coordinator");
    let st = cluster.store_stats();
    assert!(st.bytes_in > 0, "eviction streamed no KV to the host tier");
    assert_eq!(st.blobs, n, "one blob per rank, not one gathered blob");

    // Resume in a different slot; the continuation must keep matching
    // the uninterrupted reference.
    cluster.restore_slot(2, &snap).unwrap();
    assert_eq!(cluster.store_stats().blobs, 0);
    tokens[2] = tokens[0];
    for _ in 0..3 {
        let (next, _) = cluster.decode_step(&tokens).unwrap();
        assert_eq!(next[2], ref_stream.remove(0),
                   "restored session diverged from the reference");
        tokens = next;
    }
    cluster.shutdown();
}

fn churn_workload() -> Workload {
    Workload {
        num_requests: 12,
        prompt_len: (4, 8),
        gen_len: (6, 10),
        seed: 1234,
        arrival_rate: 0.4,
        burst: 1,
        turns: 3,
        idle_steps: 6,
    }
}

fn completed_tokens(server: &Server) -> HashMap<u64, Vec<i32>> {
    server.router.completed.iter()
        .map(|st| (st.req.id, st.generated.clone()))
        .collect()
}

/// The acceptance pin: total session KV >= 4x the resident budget, yet
/// every session is admitted and completes, with tokens bit-identical
/// to a run under the full physical budget (no churn at all).
#[test]
fn oversubscribed_population_completes_bit_identically() {
    let wl = churn_workload();
    const RESIDENT_BUDGET: usize = 80;

    // Uncontended reference: full physical budget, offload disabled.
    let cc = ClusterConfig::new(MODEL, layout());
    let Some(cluster) = cluster_or_skip(cc) else { return };
    let demand: usize = wl.generate(cluster.cfg.vocab).iter()
        .map(|r| r.kv_tokens()).sum();
    assert!(demand >= 4 * RESIDENT_BUDGET,
            "population demands {demand} KV tokens, want >= 4x the \
             {RESIDENT_BUDGET}-token resident budget");
    let physical = cluster.kv_budget_tokens();
    let mut generous = Server::with_budgets(cluster, physical, 0);
    let ref_report = generous.run(&wl, 100_000).unwrap();
    assert_eq!(ref_report.completed, wl.num_requests);
    assert_eq!(ref_report.rejected, 0);
    assert_eq!(ref_report.metrics.evictions, 0,
               "the generous run must not churn");
    let want = completed_tokens(&generous);
    generous.cluster.shutdown();

    // Churned run: a resident budget the population oversubscribes 4x,
    // with an ample host tier to absorb the evictions.
    let cc = ClusterConfig::new(MODEL, layout());
    let Some(cluster) = cluster_or_skip(cc) else { return };
    let mut server = Server::with_budgets(cluster, RESIDENT_BUDGET,
                                          10 * RESIDENT_BUDGET);
    let report = server.run(&wl, 100_000).unwrap();
    assert_eq!(report.completed, wl.num_requests,
               "every oversubscribed session must complete");
    assert_eq!(report.rejected, 0);
    assert!(report.metrics.evictions > 0,
            "a 4x-oversubscribed population must churn");
    assert_eq!(report.metrics.evictions, report.metrics.restores,
               "a drained run leaves no session parked in the host tier");
    assert!(report.metrics.peak_offloaded_tokens > 0);
    let st = server.cluster.store_stats();
    assert!(st.bytes_in > 0 && st.bytes_out > 0,
            "churn must move KV through the session store: {st:?}");
    assert_eq!(st.blobs, 0, "store must drain by completion");

    let got = completed_tokens(&server);
    assert_eq!(got.len(), want.len());
    for (id, tokens) in &want {
        assert_eq!(got.get(id), Some(tokens),
                   "session {id}: churned tokens differ from the \
                    uncontended run");
    }
    server.cluster.shutdown();
}
