//! Engine integration: sharded Helix execution must match the unsharded
//! reference executable across every model x layout in the artifacts,
//! including the HOP-B pipelined path, across enough steps to cycle the
//! round-robin KV append.
//!
//! Requires `make artifacts` plus the real PJRT backend; tests skip
//! gracefully (with a note on stderr) when either is missing so the
//! tier-1 gate runs in offline builds against the stub `xla` crate.

mod common;

use helix::engine::{ClusterConfig, CommModel, HelixCluster};
use helix::config::Layout;

use crate::common::{cluster_or_skip as cluster, manifest_or_skip as manifest};

const TOL: f32 = 1e-3;

fn run_steps(model: &str, layout: Layout, hopb: bool, steps: usize)
             -> Option<f32> {
    let mut cc = ClusterConfig::new(model, layout);
    cc.verify = true;
    cc.hopb = hopb;
    let mut cluster = cluster(cc)?;
    for s in 0..cluster.batch() {
        cluster.open_slot(s).unwrap();
    }
    let mut tokens: Vec<i32> = (0..cluster.batch() as i32).map(|i| i + 5)
        .collect();
    let mut worst = 0.0f32;
    for _ in 0..steps {
        let (next, m) = cluster.decode_step(&tokens).expect("step");
        worst = worst.max(m.max_ref_diff.unwrap());
        tokens = next;
    }
    cluster.shutdown();
    Some(worst)
}

#[test]
fn all_models_all_layouts_match_reference() {
    let Some(man) = manifest() else { return };
    for (model, entry) in &man.models {
        // Enough steps to cross a kv_block boundary and cycle ranks.
        let steps = entry.config.kv_block + 4;
        for lo in &entry.layouts {
            let Some(worst) = run_steps(model, *lo, false, steps)
            else { return };
            assert!(worst < TOL,
                    "{model} {} diverged: {worst:.3e}", lo.key());
            println!("{model} {}: worst |engine-ref| = {worst:.3e}",
                     lo.key());
        }
    }
}

#[test]
fn hopb_pipeline_is_equally_exact() {
    // The per-request pipelined attention path must produce identical
    // results to lockstep (same programs, different schedule).
    let Some(worst) = run_steps("tiny_gqa",
                                Layout::helix(2, 2, 4, 1),
                                true, 12)
    else { return };
    assert!(worst < TOL, "HOP-B path diverged: {worst:.3e}");
}

#[test]
fn comm_emulation_does_not_change_numerics() {
    let mut cc = ClusterConfig::new(
        "tiny_gqa", Layout::helix(2, 2, 4, 1));
    cc.verify = true;
    cc.comm = CommModel { scale: 50.0, ..CommModel::nvlink() };
    let Some(mut cluster) = cluster(cc) else { return };
    for s in 0..cluster.batch() {
        cluster.open_slot(s).unwrap();
    }
    let (_, m) = cluster.decode_step(&[1, 2, 3, 4]).unwrap();
    assert!(m.max_ref_diff.unwrap() < TOL);
    assert!(cluster.comm_total.as_secs_f64() > 0.0,
            "emulated comm must be accounted");
    cluster.shutdown();
}

#[test]
fn partial_batch_and_slot_reuse() {
    let mut cc = ClusterConfig::new(
        "tiny_gqa", Layout::helix(2, 2, 4, 1));
    cc.verify = true;
    let Some(mut cluster) = cluster(cc) else { return };
    // Only slots 0 and 2 live.
    cluster.open_slot(0).unwrap();
    cluster.open_slot(2).unwrap();
    for step in 0..6 {
        let (_, m) = cluster.decode_step(&[9, 0, 17, 0]).unwrap();
        assert!(m.max_ref_diff.unwrap() < TOL, "step {step}");
    }
    assert_eq!(cluster.lens, vec![6, 0, 6, 0]);
    assert_eq!(cluster.active_count(), 2);
    assert_eq!(cluster.live_kv_tokens(), 12);
    // Evict slot 0 and admit a fresh request into it.
    cluster.close_slot(0);
    cluster.open_slot(0).unwrap();
    assert_eq!(cluster.lens[0], 0);
    for _ in 0..4 {
        let (_, m) = cluster.decode_step(&[3, 0, 21, 0]).unwrap();
        assert!(m.max_ref_diff.unwrap() < TOL);
    }
    assert_eq!(cluster.lens, vec![4, 0, 10, 0]);
    cluster.shutdown();
}

#[test]
fn long_decode_crosses_many_kv_blocks() {
    // 3+ full round-robin cycles on the kvp=4 layout.
    let Some(worst) = run_steps("tiny_gqa",
                                Layout::helix(4, 1, 4, 1),
                                false, 3 * 16 * 4 / 4)
    else { return };
    assert!(worst < TOL, "long decode diverged: {worst:.3e}");
}

#[test]
fn fault_injection_surfaces_rank_errors() {
    let cc = ClusterConfig::new(
        "tiny_gqa", Layout::helix(2, 2, 4, 1));
    let Some(mut cluster) = cluster(cc) else { return };
    let err = cluster.inject_fault(1, "simulated XID").unwrap();
    assert!(err.contains("simulated XID"), "got {err:?}");
    // The pool survives an injected command failure and keeps serving.
    cluster.open_slot(0).unwrap();
    let (next, _) = cluster.decode_step(&[1, 0, 0, 0]).unwrap();
    assert_eq!(next.len(), 4);
    cluster.shutdown();
}

#[test]
fn unknown_layout_is_rejected() {
    if manifest().is_none() {
        return;
    }
    let cc = ClusterConfig::new(
        "tiny_gqa", Layout::helix(8, 1, 8, 1));
    let err = HelixCluster::new(cc).err().expect("must fail");
    assert!(format!("{err:#}").contains("not in artifacts"));
}

#[test]
fn kv_overflow_is_an_error_not_corruption() {
    let mut cc = ClusterConfig::new(
        "tiny_gqa", Layout::helix(1, 1, 1, 1));
    cc.verify = false;
    let Some(mut cluster) = cluster(cc) else { return };
    cluster.open_slot(0).unwrap();
    let cap = cluster.cfg.seq_cap;
    let mut failed = false;
    for _ in 0..cap + 2 {
        if cluster.decode_step(&[1, 0, 0, 0]).is_err() {
            failed = true;
            break;
        }
    }
    assert!(failed, "decoding past seq_cap must fail loudly");
}
