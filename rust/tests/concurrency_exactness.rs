//! Determinism under concurrency: the decoded token stream must be a
//! pure function of (model, layout) — invariant to the native worker
//! count, to modeled link delays, and to the lockstep/HOP-B schedule,
//! in every combination. This is the exactness contract the concurrent
//! runtime has to keep: injected comm waits and pipelined dispatch may
//! reorder wall-clock events, never arithmetic.
//!
//! One #[test] on purpose: the matrix mutates `HELIX_NATIVE_THREADS`,
//! which is process-global state — parallel tests in this binary would
//! race it.

mod common;

use helix::config::{KvDtype, Layout};
use helix::engine::{ClusterConfig, CommModel};

use crate::common::cluster_or_skip;

const STEPS: usize = 8;

fn decode_tokens(model: &str, layout: Layout, hopb: bool,
                 comm: Option<CommModel>) -> Option<Vec<Vec<i32>>> {
    let mut cc = ClusterConfig::new(model, layout);
    cc.hopb = hopb;
    if let Some(c) = comm {
        cc.comm = c;
        cc.a2a_comm = Some(c);
    }
    let mut cluster = cluster_or_skip(cc)?;
    for s in 0..cluster.batch() {
        cluster.open_slot(s).unwrap();
    }
    let mut tokens: Vec<i32> = (0..cluster.batch() as i32).map(|i| i + 5)
        .collect();
    let mut stream = Vec::with_capacity(STEPS);
    for _ in 0..STEPS {
        let (next, _) = cluster.decode_step(&tokens).expect("step");
        stream.push(next.clone());
        tokens = next;
    }
    cluster.shutdown();
    Some(stream)
}

#[test]
fn tokens_invariant_to_threads_comm_and_schedule() {
    // Bandwidth-only link, fast enough to keep the matrix quick but
    // real enough that every collective actually charges and waits.
    let link = CommModel { latency_s: 0.0, bw_bytes_per_s: 2.0e7,
                           scale: 1.0 };
    // kv_dtype is pinned to f32 explicitly: the bit-exactness contract
    // is a property of the f32 KV tier. Quantized tiers (f16/int8) are
    // deterministic too, but validate against per-dtype tolerance
    // suites instead (tests/native_kernels.rs, tests/session_offload.rs).
    let f32_kv = |lo: Layout| Layout { kv_dtype: KvDtype::F32, ..lo };
    let cases = [("tiny_gqa", f32_kv(Layout::helix(2, 2, 4, 1))),
                 ("tiny_moe", f32_kv(Layout::helix(2, 2, 2, 2)))];
    for (model, layout) in cases {
        let mut reference: Option<Vec<Vec<i32>>> = None;
        for threads in ["1", "4"] {
            std::env::set_var("HELIX_NATIVE_THREADS", threads);
            for comm in [None, Some(link)] {
                for hopb in [false, true] {
                    let Some(stream) =
                        decode_tokens(model, layout, hopb, comm)
                    else {
                        std::env::remove_var("HELIX_NATIVE_THREADS");
                        return; // pjrt-without-artifacts environment
                    };
                    match &reference {
                        None => reference = Some(stream),
                        Some(want) => assert_eq!(
                            want, &stream,
                            "{model} {} diverged: threads={threads} \
                             comm={} hopb={hopb}", layout.key(),
                            comm.is_some()),
                    }
                }
            }
        }
    }
    std::env::remove_var("HELIX_NATIVE_THREADS");
}
