//! Planner -> engine integration: the PR 4 acceptance pin.
//!
//! * `helix plan --model <m> | helix serve --plan -` (the real binary,
//!   a real pipe) boots a cluster whose layout equals the sweep's
//!   top-ranked point.
//! * A `Plan` JSON round-trip yields an *identical* cluster
//!   configuration (model config + layout), and `Server::from_plan`
//!   never oversubscribes the physical KV pool.
//! * Every engine model's manifest layouts validate under BOTH model
//!   descriptions (the one-registry invariant), and every plan the
//!   planner emits for an engine model is bootable.

mod common;

use std::io::Write;
use std::process::{Command, Stdio};

use helix::config::{registry, Hardware};
use helix::engine::HelixCluster;
use helix::plan::{Plan, Planner};
use helix::serve::{Server, Workload};
use helix::util::Json;

fn planner(model: &str) -> Planner {
    Planner::new(model, Hardware::gb200_nvl72()).unwrap()
}

/// The acceptance test, through the library API: top-ranked plan ->
/// JSON -> parsed plan -> live cluster, layout pinned to the sweep's
/// winner and numerics equal to a directly-constructed cluster.
#[test]
fn top_plan_roundtrips_to_an_identical_cluster() {
    let Some(_probe) = common::manifest_or_skip() else { return };
    let best = planner("tiny_gqa").best().unwrap();

    // serialize -> parse -> identical plan ...
    let j = Json::parse(&best.to_json().to_string()).unwrap();
    let parsed = Plan::from_json(&j).unwrap();
    assert_eq!(parsed, best);

    // ... -> identical cluster configuration.
    let Some(a) = common::cluster_or_skip(
        helix::engine::ClusterConfig::from_plan(&best)) else { return };
    let b = HelixCluster::from_plan(&parsed).unwrap();
    assert_eq!(a.layout, best.layout, "cluster layout != planned layout");
    assert_eq!(a.layout, b.layout);
    assert_eq!(a.cfg, b.cfg, "round-trip changed the model config");

    // The plan's KV budget never oversubscribes the physical pool.
    let server = Server::from_plan(&best).unwrap();
    assert!(server.router.budget().budget_tokens
            <= server.cluster.kv_budget_tokens());
    assert_eq!(server.cluster.layout, best.layout);
}

/// `helix plan --model tiny_gqa | helix serve --plan -` — the actual
/// binary, stdout piped to stdin — boots the sweep's top-ranked layout.
#[test]
fn plan_pipes_into_serve() {
    let Some(_probe) = common::cluster_or_skip(
        helix::engine::ClusterConfig::new(
            "tiny_gqa", helix::config::Layout::helix(1, 1, 1, 1)))
    else { return };
    let expected = planner("tiny_gqa").best().unwrap();

    let bin = env!("CARGO_BIN_EXE_helix");
    let plan_out = Command::new(bin)
        .args(["plan", "--model", "tiny_gqa", "--top", "3"])
        .output()
        .expect("running `helix plan`");
    assert!(plan_out.status.success(), "helix plan failed: {}",
            String::from_utf8_lossy(&plan_out.stderr));
    // stdout is pure JSON (the human summary goes to stderr).
    let doc = Json::parse(std::str::from_utf8(&plan_out.stdout).unwrap())
        .expect("helix plan stdout must be valid JSON");
    let top = Plan::from_json_doc(&doc).unwrap();
    assert_eq!(top.layout, expected.layout,
               "CLI top plan != library top plan");

    let mut serve = Command::new(bin)
        .args(["serve", "--plan", "-", "--requests", "3", "--prompt-min",
               "2", "--prompt-max", "4", "--gen-min", "2", "--gen-max", "4"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawning `helix serve --plan -`");
    serve.stdin.take().unwrap()
        .write_all(&plan_out.stdout)
        .expect("writing plan document to serve stdin");
    let out = serve.wait_with_output().expect("waiting for serve");
    assert!(out.status.success(), "helix serve --plan - failed: {}",
            String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains(&format!("[{}]", expected.layout.key())),
            "serve did not boot the planned layout {}:\n{stdout}",
            expected.layout.key());
    assert!(stdout.contains("requests completed : 3"),
            "planned serve did not complete the trace:\n{stdout}");
}

/// One registry, one truth: every manifest layout of every engine model
/// validates under both the engine config and the derived simulator
/// spec, and every plan the planner emits is in the manifest set.
#[test]
fn every_engine_plan_is_bootable() {
    let Some(manifest) = common::manifest_or_skip() else { return };
    for name in manifest.models.keys() {
        let handle = registry::lookup_in(Some(&manifest), name).unwrap();
        let cfg = handle.engine.as_ref().unwrap();
        for lo in &handle.layouts {
            lo.validate_engine(cfg).unwrap_or_else(|e| {
                panic!("{name}: manifest layout {} fails engine \
                        validation: {e:#}", lo.key())
            });
            lo.validate(&handle.spec, false).unwrap_or_else(|e| {
                panic!("{name}: manifest layout {} fails sim validation \
                        against the derived spec: {e:#}", lo.key())
            });
        }
        let plans = planner(name).plan().unwrap();
        assert!(!plans.is_empty(), "{name}: planner found no plans");
        for p in &plans {
            assert!(handle.layouts.contains(&p.layout),
                    "{name}: planner emitted unbootable layout {}",
                    p.layout.key());
            assert!(p.kv_budget > 0, "{name}: zero KV budget");
        }
    }
}

/// Serving through a plan produces the same tokens as serving through
/// the hand-built path — planning changes provisioning, not numerics.
#[test]
fn planned_serving_matches_direct_serving() {
    let Some(_probe) = common::manifest_or_skip() else { return };
    let best = planner("tiny_gqa").best().unwrap();
    let workload = Workload { num_requests: 4, prompt_len: (2, 4),
                              gen_len: (3, 5), seed: 123,
                              arrival_rate: 0.0, burst: 1,
                              turns: 1, idle_steps: 0 };

    let mut planned = match Server::from_plan(&best) {
        Ok(s) => s,
        Err(_) => return, // backend unavailable (pjrt pinned, no closure)
    };
    planned.run(&workload, 10_000).unwrap();
    let mut a: Vec<(u64, Vec<i32>)> = planned.router.completed.iter()
        .map(|st| (st.req.id, st.generated.clone()))
        .collect();
    a.sort();

    let mut cc = helix::engine::ClusterConfig::new("tiny_gqa", best.layout);
    cc.hopb = best.strategy == "helix";
    let Some(c) = common::cluster_or_skip(cc) else { return };
    let mut direct = Server::new(c);
    direct.run(&workload, 10_000).unwrap();
    let mut b: Vec<(u64, Vec<i32>)> = direct.router.completed.iter()
        .map(|st| (st.req.id, st.generated.clone()))
        .collect();
    b.sort();
    assert_eq!(a, b, "planned vs direct serving diverged");
}

/// Unknown model names fail loudly with the candidate list — the
/// classic operational footgun is planning against a different
/// artifact root than serving; the error must name the problem.
#[test]
fn plans_for_unknown_models_fail_loudly() {
    let Some(_m) = common::manifest_or_skip() else { return };
    let e = Planner::new("no_such_model", Hardware::gb200_nvl72())
        .unwrap_err();
    assert!(format!("{e:#}").contains("unknown model"));
}
