//! Property tests over the analytic simulator: Pareto invariants, HOP-B
//! bounds, memory-model monotonicity, sweep validity.

use helix::config::{Hardware, KvDtype, Layout, ModelSpec};
use helix::sim::decode::{evaluate, Strategy};
use helix::sim::sweep::{self, SweepBounds};
use helix::sim::{hopb, memory, Frontier};
use helix::util::prop::forall;

fn hw() -> Hardware {
    Hardware::gb200_nvl72()
}

#[test]
fn frontier_has_no_dominated_points() {
    forall("frontier dominance", 30, |rng| {
        let m = if rng.bool(0.5) {
            ModelSpec::llama_405b()
        } else {
            ModelSpec::deepseek_r1()
        };
        let bounds = SweepBounds {
            max_gpus: *rng.choose(&[8usize, 16, 64]),
            max_batch: 256,
            seq_len: *rng.choose(&[1.0e5, 1.0e6]),
        };
        let pts = sweep::sweep_baseline(&m, &hw(), &bounds);
        let f = Frontier::from_points(pts.clone());
        // No point in the raw sweep dominates any frontier point.
        for fp in &f.points {
            for p in &pts {
                assert!(
                    !(p.interactivity > fp.interactivity
                      && p.throughput_per_gpu > fp.throughput_per_gpu),
                    "frontier point dominated"
                );
            }
        }
        // Frontier is monotone.
        for w in f.points.windows(2) {
            assert!(w[0].interactivity < w[1].interactivity);
            assert!(w[0].throughput_per_gpu >= w[1].throughput_per_gpu);
        }
    });
}

#[test]
fn hopb_exposed_comm_bounds() {
    forall("hopb bounds", 1000, |rng| {
        let c = rng.f64() * 100.0;
        let m = rng.f64() * 100.0;
        let chunks = rng.range(1, 64);
        let e = hopb::exposed_comm(c, m, chunks, true);
        assert!(e >= -1e-9, "negative exposure");
        assert!(e <= m + 1e-9, "exposure exceeds total comm");
        // Overlap never helps less than lockstep.
        assert!(e <= hopb::exposed_comm(c, m, chunks, false) + 1e-9);
        // More chunks never hurt.
        if chunks >= 2 {
            let e2 = hopb::exposed_comm(c, m, chunks * 2, true);
            assert!(e2 <= e + 1e-9, "more chunks increased exposure");
        }
    });
}

#[test]
fn kv_read_monotone_in_batch_and_s() {
    forall("kv read monotonicity", 300, |rng| {
        let m = ModelSpec::llama_405b();
        let h = hw();
        let b = rng.range(1, 128);
        let s = 1e4 + rng.f64() * 4e6;
        let tpa = *rng.choose(&[1usize, 2, 4, 8]);
        let kvp = *rng.choose(&[1usize, 2, 4, 8]);
        let base = memory::kv_read_bytes_per_gpu(&m, &h, b, s, tpa, kvp);
        assert!(memory::kv_read_bytes_per_gpu(&m, &h, b + 1, s, tpa, kvp)
                > base);
        assert!(memory::kv_read_bytes_per_gpu(&m, &h, b, s * 2.0, tpa, kvp)
                > base);
        // KVP strictly reduces per-GPU traffic.
        assert!(memory::kv_read_bytes_per_gpu(&m, &h, b, s, tpa, kvp * 2)
                < base);
    });
}

#[test]
fn capacity_monotone_in_batch() {
    forall("capacity monotone", 200, |rng| {
        let m = ModelSpec::llama_405b();
        let h = hw();
        let lo = Layout::helix(*rng.choose(&[1usize, 2, 4, 8]), 8, 0, 1);
        let lo = Layout { tpf: lo.n(), ..lo };
        if lo.validate(&m, false).is_err() {
            return;
        }
        let s = 1.0e6;
        let mut prev = true;
        for b in [1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
            let fits = memory::fits_capacity(&m, &h, &lo, b, s);
            assert!(prev || !fits, "capacity must be monotone in batch");
            prev = fits;
        }
    });
}

#[test]
fn evaluate_rejects_what_capacity_rejects() {
    forall("evaluate respects capacity", 100, |rng| {
        let m = ModelSpec::deepseek_r1();
        let h = hw();
        let kvp = *rng.choose(&[1usize, 4, 16, 64]);
        let lo = Layout { kvp, tpa: 1, tpf: kvp, ep: 1, pp: 1, page: 0,
                          kv_dtype: KvDtype::F32 };
        let b = *rng.choose(&[1usize, 16, 256, 1024]);
        let p = evaluate(&m, &h, Strategy::Helix { hopb: true }, &lo, b,
                         1.0e6);
        let fits = memory::fits_capacity(&m, &h, &lo, b, 1.0e6)
            && lo.gpus() <= h.max_domain;
        assert_eq!(p.is_some(), fits);
        if let Some(p) = p {
            assert!(p.ttl > 0.0 && p.ttl.is_finite());
            assert!(p.throughput_per_gpu > 0.0);
        }
    });
}

#[test]
fn helix_ttl_never_worse_than_medha_same_pool() {
    // Same pool, same batch: decoupling the FFN grid + overlap must not
    // lose to the tied-TP exposed-comm baseline.
    forall("helix <= medha", 100, |rng| {
        let m = ModelSpec::llama_405b();
        let h = hw();
        let tp = *rng.choose(&[2usize, 4, 8]);
        let kvp = *rng.choose(&[2usize, 4, 8]);
        let b = *rng.choose(&[1usize, 4, 8]);
        let lo_medha = Layout { kvp, tpa: tp, tpf: tp, ep: 1, pp: 1, page: 0,
                                kv_dtype: KvDtype::F32 };
        let lo_helix = Layout { kvp, tpa: tp, tpf: kvp * tp, ep: 1, pp: 1,
                                page: 0, kv_dtype: KvDtype::F32 };
        let me = evaluate(&m, &h, Strategy::MedhaKvp, &lo_medha, b, 1.0e6);
        let he = evaluate(&m, &h, Strategy::Helix { hopb: true }, &lo_helix,
                          b, 1.0e6);
        if let (Some(me), Some(he)) = (me, he) {
            assert!(he.ttl <= me.ttl * 1.001,
                    "helix {} vs medha {} (tp={tp} kvp={kvp} b={b})",
                    he.ttl, me.ttl);
        }
    });
}

#[test]
fn sweep_points_all_satisfy_domain_and_capacity() {
    let m = ModelSpec::deepseek_r1();
    let h = hw();
    let bounds = SweepBounds { max_gpus: 64, max_batch: 256, seq_len: 1.0e6 };
    for strat in [Strategy::Helix { hopb: true }, Strategy::Tp,
                  Strategy::MedhaKvp, Strategy::DpEp] {
        for p in sweep::sweep_strategy(&m, &h, strat, &bounds) {
            assert!(p.gpus <= 64);
            assert!(memory::fits_capacity(&m, &h, &p.layout,
                                          p.batch * p.layout.pp, 1.0e6));
            assert!(p.interactivity.is_finite());
        }
    }
}

#[test]
fn sparse_attention_cuts_reads_not_capacity() {
    // Paper S6: NSA-style sparsity reduces KV read bandwidth but not
    // memory capacity requirements.
    let dense = ModelSpec::llama_405b();
    let sparse = ModelSpec::llama_405b().with_sparse_attention(0.125);
    let h = hw();
    let read_d = memory::kv_read_bytes_per_gpu(&dense, &h, 8, 1.0e6, 8, 4);
    let read_s = memory::kv_read_bytes_per_gpu(&sparse, &h, 8, 1.0e6, 8, 4);
    assert!((read_d / read_s - 8.0).abs() < 1e-9);
    let cap_d = memory::kv_stored_bytes_per_gpu(&dense, &h, 8, 1.0e6, 8, 4);
    let cap_s = memory::kv_stored_bytes_per_gpu(&sparse, &h, 8, 1.0e6, 8, 4);
    assert_eq!(cap_d, cap_s, "sparsity must not shrink stored bytes");
    // Helix still matters under sparsity: batch capacity is unchanged,
    // so KVP remains the only way to fit multi-million-token caches.
    let lo = Layout::tp(8);
    assert!(!memory::fits_capacity(&sparse, &h, &lo, 64, 1.0e6));
}

#[test]
fn helix_advantage_grows_with_context_length() {
    // Paper S5: in the short-context regime Helix degenerates to the
    // patterns serving frameworks already use — its edge over the best
    // baseline must shrink as S shrinks and grow as S grows.
    let m = ModelSpec::llama_405b();
    let h = hw();
    let gain_at = |s: f64| {
        let bounds = SweepBounds { max_gpus: 64, max_batch: 256,
                                   seq_len: s };
        let base = Frontier::from_points(
            sweep::sweep_baseline(&m, &h, &bounds));
        let helix = Frontier::from_points(sweep::sweep_strategy(
            &m, &h, Strategy::Helix { hopb: true }, &bounds));
        helix.max_interactivity() / base.max_interactivity()
    };
    let short = gain_at(2048.0);
    let medium = gain_at(262_144.0);
    let long = gain_at(4.0e6);
    assert!(short < 1.15, "short-context gain should vanish: {short}");
    assert!(long > medium && medium >= short * 0.95,
            "gain must grow with S: {short} -> {medium} -> {long}");
}

#[test]
fn precision_does_not_change_who_wins() {
    // Robustness ablation: FP8/FP16 shift absolute times but not the
    // Helix-vs-baseline ordering.
    use helix::config::hardware::Precision;
    let m = ModelSpec::llama_405b();
    for precision in [Precision::Fp4, Precision::Fp8, Precision::Fp16] {
        let mut h = hw();
        h.precision = precision;
        let bounds = SweepBounds { max_gpus: 64, max_batch: 128,
                                   seq_len: 1.0e6 };
        let base = Frontier::from_points(
            sweep::sweep_baseline(&m, &h, &bounds));
        let helix = Frontier::from_points(sweep::sweep_strategy(
            &m, &h, Strategy::Helix { hopb: true }, &bounds));
        assert!(helix.max_interactivity() > base.max_interactivity(),
                "{precision:?}: helix must keep winning interactivity");
    }
}
