//! L3 hot-path microbenches: the host-tensor operations on the
//! coordinator's critical path (All-to-All reshuffle, All-Reduce
//! accumulation, KV append, weight slicing) — the targets of the SPerf
//! optimization pass.

use helix::runtime::HostTensor;
use helix::util::bench::bench;
use helix::util::Rng;

fn randn(rng: &mut Rng, shape: &[usize]) -> HostTensor {
    let n: usize = shape.iter().product();
    HostTensor::from_f32((0..n).map(|_| rng.f32_signed()).collect(), shape)
        .unwrap()
}

fn main() {
    let mut rng = Rng::new(1);

    // Shapes from tiny_gqa under kvp2 x tpa2: partials [4, 4, 32].
    let partials: Vec<HostTensor> =
        (0..4).map(|_| randn(&mut rng, &[4, 4, 32])).collect();
    bench("l3/a2a_reshuffle_slice_stack", 10, 500, || {
        let mut stacks = Vec::with_capacity(4);
        for k in 0..2usize {
            let a = partials[0].slice_axis(1, k * 2, 2).unwrap();
            let b = partials[1].slice_axis(1, k * 2, 2).unwrap();
            stacks.push(HostTensor::stack(&[&a, &b]).unwrap());
        }
        std::hint::black_box(stacks);
    });

    // The zero-copy reshuffle the engine actually runs now: borrowed
    // strided views + one gather into the destination stack.
    bench("l3/a2a_reshuffle_view_gather", 10, 500, || {
        let mut stacks = Vec::with_capacity(4);
        for k in 0..2usize {
            let views = [partials[0].slice_axis_view(1, k * 2, 2).unwrap(),
                         partials[1].slice_axis_view(1, k * 2, 2).unwrap()];
            stacks.push(HostTensor::stack_views(&views).unwrap());
        }
        std::hint::black_box(stacks);
    });

    // Broadcast cost: Arc clone per destination rank (was a deep copy).
    let bx = randn(&mut rng, &[8, 16384]);
    bench("l3/broadcast_clone_8x(8x16384)", 10, 5000, || {
        let clones: Vec<HostTensor> = (0..8).map(|_| bx.clone()).collect();
        std::hint::black_box(clones);
    });

    // All-Reduce accumulation over N=4 partials of [B=4, H=256].
    let parts: Vec<HostTensor> =
        (0..4).map(|_| randn(&mut rng, &[4, 256])).collect();
    bench("l3/allreduce_sum_4x(4x256)", 10, 2000, || {
        let mut acc = HostTensor::zeros(&[4, 256]);
        for p in &parts {
            acc.add_assign(p).unwrap();
        }
        std::hint::black_box(acc);
    });

    // Bigger tensors (llama-like slice): 8 x [8, 16384].
    let big: Vec<HostTensor> =
        (0..8).map(|_| randn(&mut rng, &[8, 16384])).collect();
    bench("l3/allreduce_sum_8x(8x16384)", 5, 200, || {
        let mut acc = HostTensor::zeros(&[8, 16384]);
        for p in &big {
            acc.add_assign(p).unwrap();
        }
        std::hint::black_box(acc);
    });

    // Weight slicing (engine init path): [256, 1024] column slice.
    let w = randn(&mut rng, &[256, 1024]);
    bench("l3/weight_slice_cols_256x1024/4", 10, 1000, || {
        std::hint::black_box(w.slice_axis(1, 256, 256).unwrap());
    });

    // Literal conversion round-trip proxy: clone + reshape of [4,256].
    let x = randn(&mut rng, &[4, 256]);
    bench("l3/tensor_clone_reshape", 10, 5000, || {
        std::hint::black_box(x.reshape(&[1024]).unwrap());
    });

    // KV row view (HOP-B per-request path): [4, 2, 128, 32] row slice —
    // now a zero-copy Arc view (shared storage + offset).
    let kc = randn(&mut rng, &[4, 2, 128, 32]);
    bench("l3/kv_row_view", 10, 2000, || {
        std::hint::black_box(kc.slice_axis(0, 2, 1).unwrap());
    });
}
