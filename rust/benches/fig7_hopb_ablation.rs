//! Figure 7 regeneration: HOP-B ON vs OFF for both models at 1M context.
//!
//! The paper isolates HOP-B "by turning it off during attention" —
//! communication and computation execute strictly sequentially for the
//! *same configuration*. We therefore re-evaluate every ON-frontier
//! configuration with overlap disabled and report the tokens/s/user
//! degradation, exactly the Fig 7 comparison.
//!
//! Paper: DeepSeek-R1 loses only ~1% (its All-to-All is ~1% of decode
//! latency; latent projections and multi-expert GEMMs dominate), while
//! Llama-405B loses up to ~12%.

use helix::config::{Hardware, ModelSpec};
use helix::sim::decode::{evaluate, Strategy};
use helix::sim::sweep::{self, SweepBounds};
use helix::sim::Frontier;
use helix::util::bench::bench_once;
use helix::util::table::Table;

fn ablate(m: &ModelSpec) -> (f64, f64) {
    let hw = Hardware::gb200_nvl72();
    let bounds = SweepBounds::default();
    let mut on_pts = Vec::new();
    bench_once(&format!("fig7/{}_sweep", m.name), || {
        on_pts = sweep::sweep_strategy(m, &hw, Strategy::Helix { hopb: true },
                                       &bounds);
    });
    let on = Frontier::from_points(on_pts);

    println!("\n## Figure 7: HOP-B ablation — {} (same config, overlap off)",
             m.name);
    let mut t = Table::new(["layout", "batch", "user ON", "user OFF",
                            "drop"]);
    let mut worst: f64 = 0.0;
    let mut sum = 0.0;
    let mut count = 0;
    for p in &on.points {
        let off = evaluate(m, &hw, Strategy::Helix { hopb: false },
                           &p.layout, p.batch, bounds.seq_len)
            .expect("same config must remain valid");
        let drop = 1.0 - off.interactivity / p.interactivity;
        worst = worst.max(drop);
        sum += drop;
        count += 1;
        t.row([format!("{}", p.layout), format!("{}", p.batch),
               format!("{:.1}", p.interactivity),
               format!("{:.1}", off.interactivity),
               format!("{:.1}%", drop * 100.0)]);
    }
    print!("{}", t.render());
    let mean = sum / count.max(1) as f64;
    println!("tokens/s/user drop: max {:.1}% | mean {:.1}%", worst * 100.0,
             mean * 100.0);
    (worst, mean)
}

fn main() {
    let (dsr1_max, dsr1_mean) = ablate(&ModelSpec::deepseek_r1());
    let (llama_max, llama_mean) = ablate(&ModelSpec::llama_405b());

    println!("\npaper: DSR1 ~1% | Llama-405B up to ~12%.  measured (max): \
              DSR1 {:.1}% | Llama {:.1}%", dsr1_max * 100.0,
             llama_max * 100.0);
    // Shape: the contrast must hold — Llama suffers more than DSR1, DSR1
    // barely notices, Llama's loss is visible.
    // Our collective model is NVLS-latency-dominated at these tiny
    // per-token volumes, which compresses the paper's 1%-vs-12% contrast
    // into low single digits (see EXPERIMENTS.md); the qualitative shape
    // — small for DSR1, visible and larger for Llama — must still hold.
    assert!(llama_mean >= dsr1_mean,
            "HOP-B must matter at least as much for Llama as for DSR1");
    assert!(dsr1_max < 0.08,
            "DSR1 degradation should be small (paper ~1%), got {dsr1_max}");
    assert!(llama_max > 0.02,
            "Llama degradation should be visible (paper ~12%), got \
             {llama_max}");
    println!("fig7 shape checks PASSED");
}
