//! Figure 6 regeneration: Llama-405B @ 1M context Pareto frontier,
//! Helix vs TP sharding vs Medha-style vanilla KVP.
//!
//! Paper headline: 1.13x max interactivity and ~4x throughput/batch
//! capacity vs TP; Medha trails because its FFN stays on the tied TP
//! group and all communication is exposed.

use helix::config::{Hardware, ModelSpec};
use helix::sim::decode::Strategy;
use helix::sim::sweep::{self, SweepBounds};
use helix::sim::{pareto, Frontier};
use helix::util::bench::bench_once;
use helix::util::table::Table;

fn main() {
    let m = ModelSpec::llama_405b();
    let hw = Hardware::gb200_nvl72();
    let bounds = SweepBounds::default();

    let mut tp = Vec::new();
    let mut medha = Vec::new();
    let mut helix = Vec::new();
    bench_once("fig6/llama_sweep", || {
        tp = sweep::sweep_strategy(&m, &hw, Strategy::Tp, &bounds);
        medha = sweep::sweep_strategy(&m, &hw, Strategy::MedhaKvp, &bounds);
        helix = sweep::sweep_strategy(&m, &hw, Strategy::Helix { hopb: true },
                                      &bounds);
    });

    let ft = Frontier::from_points(tp);
    let fm = Frontier::from_points(medha);
    let fh = Frontier::from_points(helix);
    let (ni, nt) = (ft.max_interactivity(), ft.max_throughput());

    println!("\n## Figure 6: Llama-405B @ 1M (normalized to TP-baseline max)");
    let mut t = Table::new(["series", "tok/s/user", "tok/s/gpu", "layout",
                            "batch", "gpus"]);
    for (name, f) in [("tp", &ft), ("medha", &fm), ("helix", &fh)] {
        for p in &f.points {
            t.row([name.to_string(),
                   format!("{:.3}", p.interactivity / ni),
                   format!("{:.3}", p.throughput_per_gpu / nt),
                   format!("{}", p.layout),
                   format!("{}", p.batch * p.layout.pp),
                   format!("{}", p.gpus)]);
        }
    }
    print!("{}", t.render());

    let h_tp = pareto::headline(&fh, &ft);
    let h_medha = pareto::headline(&fh, &fm);
    println!("\nhelix vs tp   : interactivity {:.2}x (paper 1.13x) | \
              throughput {:.2}x (paper ~4x) | batch {:.2}x",
             h_tp.interactivity_gain, h_tp.throughput_gain, h_tp.batch_gain);
    println!("helix vs medha: interactivity {:.2}x | throughput {:.2}x",
             h_medha.interactivity_gain, h_medha.throughput_gain);

    // Shape assertions: who wins and roughly by how much.
    assert!(h_tp.interactivity_gain > 1.05,
            "Helix must lift the TP interactivity ceiling");
    assert!(h_tp.throughput_gain > 2.0,
            "Helix must give multi-x throughput at fixed TTL");
    // Medha shards KV (so it can beat plain TP on interactivity) but its
    // tied-TP FFN + exposed comm must keep it strictly inside Helix.
    assert!(h_medha.interactivity_gain >= 1.0,
            "Medha must not beat Helix on interactivity");
    assert!(h_medha.throughput_gain >= 2.0,
            "Helix must dominate Medha's throughput (untied FFN grid)");
    assert!(fm.max_throughput() <= fh.max_throughput(),
            "Medha's tied-TP FFN must cap its throughput below Helix");
    println!("fig6 shape checks PASSED");
}
