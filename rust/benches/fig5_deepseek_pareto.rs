//! Figure 5 regeneration: DeepSeek-R1 @ 1M context throughput-vs-
//! interactivity Pareto frontier, Helix vs the best baseline
//! (TP / PP / vanilla-KVP / DP-attention+EP).
//!
//! Paper headline: up to 1.5x interactivity, up to 32x more concurrent
//! users (tokens/s/GPU) at a fixed latency budget. We assert the *shape*:
//! Helix dominates, with substantial (>1.2x / >4x) gains.

use helix::config::{Hardware, ModelSpec};
use helix::sim::decode::Strategy;
use helix::sim::sweep::{self, SweepBounds};
use helix::sim::{pareto, Frontier};
use helix::util::bench::bench_once;
use helix::util::table::Table;

fn main() {
    let m = ModelSpec::deepseek_r1();
    let hw = Hardware::gb200_nvl72();
    let bounds = SweepBounds::default();

    let mut base = Vec::new();
    let mut helix = Vec::new();
    bench_once("fig5/deepseek_sweep", || {
        base = sweep::sweep_baseline(&m, &hw, &bounds);
        helix = sweep::sweep_strategy(&m, &hw, Strategy::Helix { hopb: true },
                                      &bounds);
    });
    println!("configurations: {} valid baseline, {} valid helix (of {} \
              examined)", base.len(), helix.len(),
             sweep::config_count(&m, &bounds));

    let fb = Frontier::from_points(base);
    let fh = Frontier::from_points(helix);
    let (ni, nt) = (fb.max_interactivity(), fb.max_throughput());

    println!("\n## Figure 5: DeepSeek-R1 @ 1M (normalized to baseline max)");
    let mut t = Table::new(["series", "tok/s/user", "tok/s/gpu", "layout",
                            "batch", "gpus", "strategy"]);
    for (name, f) in [("baseline", &fb), ("helix", &fh)] {
        for p in &f.points {
            t.row([name.to_string(),
                   format!("{:.3}", p.interactivity / ni),
                   format!("{:.3}", p.throughput_per_gpu / nt),
                   format!("{}", p.layout),
                   format!("{}", p.batch * p.layout.pp),
                   format!("{}", p.gpus), p.strategy.name().to_string()]);
        }
    }
    print!("{}", t.render());

    let h = pareto::headline(&fh, &fb);
    println!("\nheadline: interactivity {:.2}x (paper: up to 1.5x) | \
              throughput {:.2}x | batch {:.2}x (paper: up to 32x)",
             h.interactivity_gain, h.throughput_gain, h.batch_gain);

    assert!(h.interactivity_gain > 1.2,
            "Helix must meaningfully extend DSR1 interactivity");
    assert!(h.batch_gain >= 4.0,
            "Helix must support multi-x more users at fixed TTL");
    println!("fig5 shape checks PASSED");
}
