//! Figure 3 regeneration: HOP-B overlap timeline with the paper's exact
//! numbers — 8 requests, 16u attention + 9.6u comm: 25.6u lockstep vs
//! ~17u pipelined.

use helix::sim::hopb;
use helix::util::bench::bench;

fn main() {
    let (chunks, c, m) = (8usize, 2.0, 1.2);
    let off = hopb::timeline(c, m, chunks, false);
    let on = hopb::timeline(c, m, chunks, true);

    println!("## Figure 3: HOP-B timeline (paper units)");
    println!("lockstep  : makespan {:.1} (paper: 25.6), exposed comm {:.1}",
             off.makespan(), off.exposed_comm());
    println!("pipelined : makespan {:.1} (paper: ~17), exposed comm {:.1}",
             on.makespan(), on.exposed_comm());
    println!("TTL saving: {:.1} units\n", off.makespan() - on.makespan());

    assert!((off.makespan() - 25.6).abs() < 1e-9);
    assert!((on.makespan() - 17.2).abs() < 1e-9);
    assert!((on.exposed_comm() - 1.2).abs() < 1e-9);

    print!("{}", on.render(64));
    println!();

    bench("fig3/timeline_build_and_measure", 10, 200, || {
        let tl = hopb::timeline(c, m, chunks, true);
        std::hint::black_box((tl.makespan(), tl.exposed_comm()));
    });
    println!("\nfig3 checks PASSED (25.6 -> 17.2 units, one chunk exposed)");
}
