//! Figure 1 regeneration: DRAM-read roofline (Appendix A formulas).
//! Left: latency vs TP width; Middle: vs KV length S; Right: vs KVP
//! width. Asserts the paper's qualitative shape (plateau at TP=K,
//! linear growth with S, sublinear TTL scaling with KVP).

use helix::config::Hardware;
use helix::sim::memory::{fig1_kv_read_time, fig1_weight_read_time};
use helix::util::bench::bench;
use helix::util::table::Table;

const B: usize = 8;
const Q: usize = 128;
const K: usize = 8;
const HSZ: usize = 128;
const H: usize = 16384;
const F: usize = 65536;

fn main() {
    let hw = Hardware::gb200_nvl72();

    println!("## Figure 1 (left): DRAM read latency vs TP width (S=1M)");
    let mut t = Table::new(["TP", "kv_ms", "weight_ms", "total_ms"]);
    let mut prev_total = f64::INFINITY;
    let mut kv_at_k = 0.0;
    for tp in [1usize, 2, 4, 8, 16, 32, 64] {
        let kv = fig1_kv_read_time(&hw, B, K, HSZ, 1e6, tp, 1);
        let w = fig1_weight_read_time(&hw, H, Q, K, HSZ, F, tp, tp);
        if tp == K {
            kv_at_k = kv;
        }
        if tp > K {
            assert_eq!(kv, kv_at_k, "KV read must plateau beyond TP=K");
        }
        let total = kv + w;
        assert!(total <= prev_total + 1e-12, "total must be monotone");
        prev_total = total;
        t.row([format!("{tp}"), format!("{:.4}", kv * 1e3),
               format!("{:.4}", w * 1e3), format!("{:.4}", total * 1e3)]);
    }
    print!("{}", t.render());

    println!("\n## Figure 1 (middle): DRAM read time vs S (TP=8)");
    let mut t = Table::new(["S", "kv_ms", "weight_ms", "kv_fraction"]);
    let w8 = fig1_weight_read_time(&hw, H, Q, K, HSZ, F, 8, 8);
    let mut prev_frac = 0.0;
    for s in [65536.0, 262144.0, 1.0e6, 2.0e6, 4.0e6, 8.0e6] {
        let kv = fig1_kv_read_time(&hw, B, K, HSZ, s, 8, 1);
        let frac = kv / (kv + w8);
        assert!(frac >= prev_frac, "KV share must grow with S");
        prev_frac = frac;
        t.row([format!("{s:.0}"), format!("{:.4}", kv * 1e3),
               format!("{:.4}", w8 * 1e3), format!("{frac:.3}")]);
    }
    print!("{}", t.render());
    assert!(prev_frac > 0.9, "at 8M tokens the KV read dominates");

    println!("\n## Figure 1 (right): DRAM read time vs KVP width (TPA=8)");
    let mut t = Table::new(["KVP", "GPUs", "kv_ms", "weight_ms(TPF=N)"]);
    let kv1 = fig1_kv_read_time(&hw, B, K, HSZ, 1e6, 8, 1);
    for kvp in [1usize, 2, 4, 8] {
        let kv = fig1_kv_read_time(&hw, B, K, HSZ, 1e6, 8, kvp);
        assert!((kv1 / kv - kvp as f64).abs() < 1e-9,
                "KV read scales 1/KVP");
        let w = fig1_weight_read_time(&hw, H, Q, K, HSZ, F, 8, 8 * kvp);
        t.row([format!("{kvp}"), format!("{}", 8 * kvp),
               format!("{:.4}", kv * 1e3), format!("{:.4}", w * 1e3)]);
    }
    print!("{}", t.render());

    println!();
    bench("fig1/full_roofline_eval", 3, 50, || {
        let mut acc = 0.0;
        for tp in [1usize, 2, 4, 8, 16, 32, 64] {
            acc += fig1_kv_read_time(&hw, B, K, HSZ, 1e6, tp, 1)
                + fig1_weight_read_time(&hw, H, Q, K, HSZ, F, tp, tp);
        }
        std::hint::black_box(acc);
    });
    println!("\nfig1 shape checks PASSED (plateau at TP=K, S-linear KV, \
              1/KVP scaling)");
}
