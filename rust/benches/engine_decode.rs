//! Engine end-to-end microbench: real decode-step latency per model and
//! layout. Runs on whichever backend `HELIX_BACKEND` selects — the
//! native backend needs no artifacts (the synthetic manifest kicks in),
//! the PJRT backend needs `make artifacts` plus the real xla crate.
//!
//! This is the measured counterpart of the simulator's TTL: it times the
//! full L3 path (broadcast -> redundant QKV -> round-robin append ->
//! flash-decode -> All-to-All + combine -> TP out-proj -> FFN grid),
//! plus the HOP-B overlap comparison under an emulated NVLink, plus a
//! context-length sweep that pins the KV-read scaling of the decode
//! step (the paper's core cost driver).
//!
//! Besides the stdout report it writes `BENCH_engine.json` (tokens/s,
//! per-phase ns, allocations per step) into `$BENCH_OUT` (default: the
//! working directory) — the machine-readable perf trajectory this repo
//! diffs across PRs (see scripts/check_bench_regression.py).

use helix::engine::{ClusterConfig, CommModel, HelixCluster};
use helix::config::{KvDtype, Layout};
use helix::runtime::Manifest;
use helix::util::bench::{alloc_count, bench, CountingAlloc, JsonReport};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Per-step aggregates a bench run hands back for cross-run comparisons
/// (the HOP-B on/off overlap ablation).
struct StepStats {
    median_s: f64,
    tokens_per_s: f64,
    attn_ns: f64,
    comm_exposed_ns: f64,
    comm_total_ns: f64,
}

impl StepStats {
    /// Exposed-comm fraction: link time the step actually paid for,
    /// over link time charged (0 when the run modeled no comm).
    fn exposed_frac(&self) -> f64 {
        if self.comm_total_ns <= 0.0 {
            0.0
        } else {
            self.comm_exposed_ns / self.comm_total_ns
        }
    }
}

fn step_bench(report: &mut JsonReport, name: &str, model: &str,
              layout: Layout, hopb: bool, paged: bool, a2a_bw: f64)
              -> Option<StepStats> {
    let mut cc = ClusterConfig::new(model, layout);
    cc.hopb = hopb;
    cc.paged = paged;
    if a2a_bw > 0.0 {
        // Slow down only the KVP All-to-All (the collective HOP-B
        // pipelines), bandwidth-dominated so overlap is observable.
        cc.a2a_comm = Some(CommModel { latency_s: 0.0,
                                       bw_bytes_per_s: a2a_bw, scale: 1.0 });
    }
    let mut cluster = match HelixCluster::new(cc) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping {name}: {e:#}");
            return None;
        }
    };
    for s in 0..cluster.batch() {
        cluster.open_slot(s).unwrap();
    }
    let tokens: Vec<i32> = (0..cluster.batch() as i32).map(|i| i + 3)
        .collect();
    let batch = cluster.batch() as f64;
    const WARMUP: u64 = 3;
    const SAMPLES: usize = 10;
    // Per-phase seconds + allocations over the measured samples only
    // (warmup steps run on a near-empty KV cache and would skew the
    // per-step averages the JSON report diffs across PRs).
    let mut phases = [0.0f64; 4];
    let mut steps = 0u64;
    let mut calls = 0u64;
    // Alloc window bounds captured inside the closure, symmetric around
    // the measured samples only — harness bookkeeping (Measurement
    // construction, report formatting) stays outside the window.
    let (mut a0, mut a1) = (0u64, 0u64);
    let m = bench(name, WARMUP as usize, SAMPLES, || {
        // Steps accumulate context, so later samples attend over more
        // KV — representative of steady-state decode.
        if calls == WARMUP {
            a0 = alloc_count();
        }
        let (next, sm) = cluster.decode_step(&tokens).unwrap();
        if calls >= WARMUP {
            phases[0] += sm.attn.as_secs_f64();
            phases[1] += sm.comm_exposed.as_secs_f64();
            phases[2] += sm.comm_total.as_secs_f64();
            phases[3] += sm.ffn.as_secs_f64();
            steps += 1;
        }
        calls += 1;
        if calls == WARMUP + SAMPLES as u64 {
            a1 = alloc_count();
        }
        std::hint::black_box(next);
    });
    let allocs_per_step = (a1 - a0) as f64 / steps as f64;
    report.add(&m);
    report.metric(&format!("{name}/tokens_per_s"), batch / m.median());
    report.metric(&format!("{name}/attn_ns_per_step"),
                  phases[0] / steps as f64 * 1e9);
    // `comm_ns_per_step` keeps its historical key with exposed
    // (critical-path) semantics; the total is reported alongside.
    report.metric(&format!("{name}/comm_ns_per_step"),
                  phases[1] / steps as f64 * 1e9);
    report.metric(&format!("{name}/comm_total_ns_per_step"),
                  phases[2] / steps as f64 * 1e9);
    report.metric(&format!("{name}/ffn_ns_per_step"),
                  phases[3] / steps as f64 * 1e9);
    report.metric(&format!("{name}/allocs_per_step"), allocs_per_step);
    cluster.shutdown();
    Some(StepStats {
        median_s: m.median(),
        tokens_per_s: batch / m.median(),
        attn_ns: phases[0] / steps as f64 * 1e9,
        comm_exposed_ns: phases[1] / steps as f64 * 1e9,
        comm_total_ns: phases[2] / steps as f64 * 1e9,
    })
}

fn write_report(report: &JsonReport) {
    let dir = std::env::var("BENCH_OUT").unwrap_or_else(|_| ".".to_string());
    match report.write(std::path::Path::new(&dir)) {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("bench report write failed: {e:#}"),
    }
}

/// Decode-step attention cost as a function of accumulated context:
/// fill the KV caches by decoding, and sample the per-phase split at
/// increasing context lengths. At long context the step must be
/// attention-dominated, with attn ns growing ~linearly in the KV length
/// (the paper's DeepSeek/Llama Fig 1 argument, measured for real).
fn context_scaling(report: &mut JsonReport, model: &str,
                   layout: Layout) {
    let cc = ClusterConfig::new(model, layout);
    let mut cluster = match HelixCluster::new(cc) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping context scaling: {e:#}");
            return;
        }
    };
    for s in 0..cluster.batch() {
        cluster.open_slot(s).unwrap();
    }
    let tokens: Vec<i32> = (0..cluster.batch() as i32).map(|i| i + 3)
        .collect();
    let cap = cluster.slot_kv_tokens();
    // Sample windows at ~1/8, 1/4, 1/2 and ~full of the per-slot KV
    // capacity; each window averages `PROBE` steps.
    const PROBE: usize = 4;
    let marks: Vec<usize> = [8, 4, 2, 1].iter()
        .map(|d| (cap / d).saturating_sub(PROBE))
        .collect();
    println!("\n## decode-step phase split vs context length \
              ({model} {})", layout.key());
    let mut len = 0usize;
    for &mark in &marks {
        while len < mark {
            cluster.decode_step(&tokens).unwrap();
            len += 1;
        }
        let (mut attn, mut ffn, mut comm) = (0.0f64, 0.0f64, 0.0f64);
        for _ in 0..PROBE {
            let (_, sm) = cluster.decode_step(&tokens).unwrap();
            attn += sm.attn.as_secs_f64();
            ffn += sm.ffn.as_secs_f64();
            comm += sm.comm_exposed.as_secs_f64();
            len += 1;
        }
        let (attn, ffn, comm) = (attn / PROBE as f64, ffn / PROBE as f64,
                                 comm / PROBE as f64);
        println!("ctx {len:>6}: attn {:>10.1} ns  ffn {:>10.1} ns  \
                  (attn share {:.0}%)", attn * 1e9, ffn * 1e9,
                 100.0 * attn / (attn + ffn + comm).max(1e-12));
        report.metric(&format!("engine/{model}/ctx{len}/attn_ns_per_step"),
                      attn * 1e9);
        report.metric(&format!("engine/{model}/ctx{len}/ffn_ns_per_step"),
                      ffn * 1e9);
    }
    cluster.shutdown();
}

/// Session offload round-trip bandwidth: decode a slot to a realistic
/// context length, then time evict -> restore trips through the
/// host-tier store. Reported per rank because the streams are per-KVP-
/// rank blobs — restore bandwidth is what scales with the layout.
fn restore_bandwidth(report: &mut JsonReport, model: &str,
                     layout: Layout) {
    let cc = ClusterConfig::new(model, layout);
    let mut cluster = match HelixCluster::new(cc) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping restore bandwidth: {e:#}");
            return;
        }
    };
    for s in 0..cluster.batch() {
        cluster.open_slot(s).unwrap();
    }
    let tokens: Vec<i32> = (0..cluster.batch() as i32).map(|i| i + 3)
        .collect();
    let fill = cluster.slot_kv_tokens() / 2;
    for _ in 0..fill {
        cluster.decode_step(&tokens).unwrap();
    }
    const TRIPS: usize = 8;
    let n = cluster.n() as f64;
    let (mut restore_s, mut bytes) = (0.0f64, 0usize);
    for trip in 0..TRIPS {
        let snap = cluster.evict_slot(0, trip as u64).unwrap();
        let before = cluster.store_stats().bytes_out;
        let t = std::time::Instant::now();
        cluster.restore_slot(0, &snap).unwrap();
        restore_s += t.elapsed().as_secs_f64();
        bytes += cluster.store_stats().bytes_out - before;
    }
    let gb_s_per_rank = bytes as f64 / n / restore_s / 1e9;
    println!("restore: {} trips x {} tokens, {:.3} GB/s per rank",
             TRIPS, fill, gb_s_per_rank);
    report.metric("kv/page/restore_gb_s_per_rank", gb_s_per_rank);
    cluster.shutdown();
}

/// Chunked-prefill ingestion: tokens/s through `prefill_chunk` plus
/// the TTFT trajectory — cumulative ingestion time when the context
/// crosses 1/8, 1/4, 1/2 and ~all of the per-slot KV capacity. This is
/// the measured counterpart of `Plan::predicted_ttft_ms`, and what the
/// `prefill/` CI gate watches (scripts/check_bench_regression.py:
/// ingestion rate present and positive, TTFT monotone in context).
fn prefill_ingestion(report: &mut JsonReport, model: &str,
                     layout: Layout) {
    let cc = ClusterConfig::new(model, layout);
    let mut cluster = match HelixCluster::new(cc) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping prefill ingestion: {e:#}");
            return;
        }
    };
    cluster.open_slot(0).unwrap();
    let vocab = cluster.cfg.vocab as i32;
    let body = cluster.slot_kv_tokens() - 1; // final token decodes
    let prompt: Vec<i32> = (0..body)
        .map(|i| 1 + (i as i32 * 13) % (vocab - 1))
        .collect();
    const CHUNK: usize = 16;
    let marks: Vec<usize> = [8, 4, 2, 1].iter().map(|d| body / d)
        .collect();
    println!("\n## chunked prefill: ingestion rate and TTFT vs context \
              ({model} {}, chunk {CHUNK})", layout.key());
    let (mut off, mut elapsed, mut mi) = (0usize, 0.0f64, 0usize);
    while off < body {
        let take = CHUNK.min(body - off);
        let pm = cluster.prefill_chunk(0, &prompt[off..off + take])
            .unwrap();
        elapsed += pm.total.as_secs_f64();
        off += take;
        while mi < marks.len() && off >= marks[mi] {
            println!("ctx {:>6}: ttft {:>9.2} ms", marks[mi],
                     elapsed * 1e3);
            report.metric(&format!("prefill/{model}/ttft_ctx{}_ms",
                                   marks[mi]), elapsed * 1e3);
            mi += 1;
        }
    }
    let tok_s = body as f64 / elapsed.max(1e-12);
    println!("ingested {body} tokens in {:.2} ms ({tok_s:.0} tok/s)",
             elapsed * 1e3);
    report.metric(&format!("prefill/{model}/chunk_tokens_per_s"), tok_s);
    cluster.shutdown();
}

/// Quantized KV tier ablation: same model, layout, and schedule — only
/// the KV storage dtype changes. Reports the per-token KV footprint and
/// the flash-decode cost at the longest context a slot holds, i.e. the
/// dequant-on-read price the 2x/4x capacity win pays (docs/QUANTKV.md).
/// The f32 row keeps the usual regression gates; the f16/int8 rows are
/// report-only in scripts/check_bench_regression.py while the tier
/// settles.
fn kv_dtype_ablation(report: &mut JsonReport, model: &str, base: Layout) {
    println!("\n## KV storage dtype: footprint vs dequant-on-read cost \
              ({model} {})", base.key());
    for kv_dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
        let layout = Layout { kv_dtype, ..base };
        let cc = ClusterConfig::new(model, layout);
        let mut cluster = match HelixCluster::new(cc) {
            Ok(c) => c,
            Err(e) => {
                // Quantized tiers need the paged native backend; under
                // a pinned PJRT run only the f32 row reports.
                eprintln!("skipping kv/dtype/{}: {e:#}", kv_dtype.name());
                continue;
            }
        };
        for s in 0..cluster.batch() {
            cluster.open_slot(s).unwrap();
        }
        let tokens: Vec<i32> = (0..cluster.batch() as i32).map(|i| i + 3)
            .collect();
        // Device KV bytes one decoded token costs across the whole
        // grid: K + V, every layer, every KV head (+ int8 block scales,
        // amortized to one f32 per kv_block tokens per head).
        let c = &cluster.cfg;
        let elems = 2 * c.layers * c.kv_heads * c.head_size;
        let mut bpt = (elems * kv_dtype.bytes_per_elem()) as f64;
        if kv_dtype == KvDtype::Int8 {
            bpt += (2 * c.layers * c.kv_heads * 4) as f64
                / c.kv_block as f64;
        }
        // Fill to ~capacity, then probe the attention phase where the
        // KV read dominates.
        const PROBE: usize = 4;
        let cap = cluster.slot_kv_tokens();
        for _ in 0..cap.saturating_sub(PROBE + 1) {
            cluster.decode_step(&tokens).unwrap();
        }
        let mut attn = 0.0f64;
        for _ in 0..PROBE {
            let (_, sm) = cluster.decode_step(&tokens).unwrap();
            attn += sm.attn.as_secs_f64();
        }
        let attn_ns = attn / PROBE as f64 * 1e9;
        println!("{:>5}: {:>8.1} KV bytes/token, attn {:>10.1} ns/step \
                  at ctx {}", kv_dtype.name(), bpt, attn_ns, cap);
        report.metric(&format!("kv/dtype/{}/bytes_per_token",
                               kv_dtype.name()), bpt);
        report.metric(&format!("kv/dtype/{}/attn_ns_longctx",
                               kv_dtype.name()), attn_ns);
        cluster.shutdown();
    }
}

/// Rank-death recovery cost: fill a batch to a realistic context,
/// checkpoint every slot to the host tier, kill a rank, then time the
/// recovery pipeline — respawn from the boot config, restore the
/// checkpoints, deterministically replay the tokens fed since. The
/// replay rate is the key number: it bounds how much decode progress a
/// checkpoint cadence can put at risk (see docs/ROBUSTNESS.md).
fn recovery_replay(report: &mut JsonReport, model: &str, layout: Layout) {
    use helix::serve::ckpt_key;
    let mut cc = ClusterConfig::new(model, layout);
    cc.recv_timeout = std::time::Duration::from_millis(2_000);
    let mut cluster = match HelixCluster::new(cc) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping recovery replay: {e:#}");
            return;
        }
    };
    let b = cluster.batch();
    for s in 0..b {
        cluster.open_slot(s).unwrap();
    }
    const FILL: usize = 32;
    const CKPT: usize = FILL / 2;
    // fed[i] is the token vector fed at step i; greedy decode makes the
    // whole trajectory replayable from any prefix.
    let mut fed: Vec<Vec<i32>> =
        vec![(0..b as i32).map(|i| i + 3).collect()];
    let mut snaps = Vec::new();
    for i in 0..FILL {
        if i == CKPT {
            for s in 0..b {
                snaps.push(cluster
                    .checkpoint_slot(s, ckpt_key(1, s as u64))
                    .unwrap());
            }
        }
        let (next, _) = cluster.decode_step(&fed[i]).unwrap();
        fed.push(next);
    }

    cluster.inject_crash(1).unwrap();
    // Detection cost is the recv_timeout knob, not a property of the
    // machine — respawn directly and time the pipeline itself.
    let cfg = cluster.config();
    let t_respawn = std::time::Instant::now();
    cluster.shutdown();
    cluster = HelixCluster::new(cfg).unwrap();
    for (s, snap) in snaps.iter().enumerate() {
        cluster.restore_slot(s, snap).unwrap();
    }
    let restore_s = t_respawn.elapsed().as_secs_f64();
    let t_replay = std::time::Instant::now();
    for i in CKPT..FILL {
        let (next, _) = cluster.decode_step(&fed[i]).unwrap();
        assert_eq!(next, fed[i + 1], "replay diverged at step {i}");
    }
    let replay_s = t_replay.elapsed().as_secs_f64();
    let replayed = ((FILL - CKPT) * b) as f64;
    println!("recovery: respawn+restore {:.2} ms, replayed {} tokens at \
              {:.1} tok/s", restore_s * 1e3, replayed as usize,
             replayed / replay_s);
    report.metric("recovery/respawn_restore_ms", restore_s * 1e3);
    report.metric("recovery/replay_tokens_per_s", replayed / replay_s);
    cluster.shutdown();
}

fn main() {
    let mut report = JsonReport::new("engine");
    let backend = std::env::var("HELIX_BACKEND")
        .unwrap_or_else(|_| "auto".to_string());
    report.note("backend", &backend);
    if Manifest::load_or_synthetic(&Manifest::default_root()).is_err() {
        eprintln!("no artifacts and no native backend (HELIX_BACKEND=\
                   {backend}) — run `make artifacts` first; skipping \
                   engine benches");
        report.note("status", "skipped: artifacts missing");
        write_report(&report);
        return;
    }
    println!("## engine decode-step latency (backend: {backend})");
    let base = step_bench(&mut report, "engine/tiny_gqa/helix_kvp2_tpa2", "tiny_gqa",
               Layout::helix(2, 2, 4, 1), false, true, 0.0);
    let _ = step_bench(&mut report, "engine/tiny_gqa/pure_kvp4", "tiny_gqa",
               Layout::helix(4, 1, 4, 1), false, true, 0.0);
    let _ = step_bench(&mut report, "engine/tiny_gqa/tp4", "tiny_gqa",
               Layout::helix(1, 4, 4, 1), false, true, 0.0);
    let _ = step_bench(&mut report, "engine/tiny_gqa/single_rank", "tiny_gqa",
               Layout::helix(1, 1, 1, 1), false, true, 0.0);
    let _ = step_bench(&mut report, "engine/tiny_mla/pure_kvp4", "tiny_mla",
               Layout::helix(4, 1, 4, 1), false, true, 0.0);
    let _ = step_bench(&mut report, "engine/tiny_moe/tpf2_ep2", "tiny_moe",
               Layout::helix(2, 2, 2, 2), false, true, 0.0);

    println!("\n## HOP-B under an emulated slow All-to-All link");
    // Calibrate the emulated link so each row's transfer takes about as
    // long as each row's attention compute: the pipeline can only hide
    // min(compute, link), so a link orders of magnitude slower than the
    // CPU interpret times would expose ~everything in both modes and
    // the ablation would measure nothing. tiny_gqa helix(2,2,4,1): 4
    // layers x 4 rows of attention, per-row A2A payload
    // (q_heads/tpa)*hsz*4*(kvp-1)/kvp = 4*32*4/2 = 256 bytes.
    let row_chunks = 4.0 * 4.0;
    let chunk_ns = base.as_ref()
        .map(|b| (b.attn_ns / row_chunks).max(60_000.0))
        .unwrap_or(200_000.0);
    let a2a_bw = 256.0 / (1.5 * chunk_ns * 1e-9);
    let off = step_bench(&mut report, "engine/tiny_gqa/a2a_hopb_off",
                         "tiny_gqa", Layout::helix(2, 2, 4, 1), false, true, a2a_bw);
    let on = step_bench(&mut report, "engine/tiny_gqa/a2a_hopb_on",
                        "tiny_gqa", Layout::helix(2, 2, 4, 1), true, true, a2a_bw);
    if let (Some(off), Some(on)) = (off, on) {
        // The measured Fig 7: same modeled bytes either way (bandwidth-
        // dominated link), so the exposed fraction isolates how much of
        // the link time the pipeline hid, and the speedup is the
        // wall-clock dividend.
        let speedup = off.median_s / on.median_s;
        println!("overlap: exposed comm fraction {:.2} (off) -> {:.2} (on), \
                  step speedup x{speedup:.2}",
                 off.exposed_frac(), on.exposed_frac());
        report.metric("overlap/a2a/exposed_frac_off", off.exposed_frac());
        report.metric("overlap/a2a/exposed_frac_on", on.exposed_frac());
        report.metric("overlap/a2a/step_speedup", speedup);
    }

    println!("\n## paged KV cache: page-table indirection vs flat arenas");
    // Same model, same layout, same step count: the only difference is
    // whether flash-decode walks the KV through page tables (serving
    // default) or the dense per-slot arenas. With the bit-exact default
    // page size the tile schedule is identical, so the gap is pure
    // indirection overhead — gated at <= 5% by
    // scripts/check_bench_regression.py.
    let paged = step_bench(&mut report, "engine/tiny_gqa/kv_paged",
                           "tiny_gqa", Layout::helix(2, 2, 4, 1), false,
                           true, 0.0);
    let flat = step_bench(&mut report, "engine/tiny_gqa/kv_flat",
                          "tiny_gqa", Layout::helix(2, 2, 4, 1), false,
                          false, 0.0);
    if let (Some(paged), Some(flat)) = (paged, flat) {
        let overhead = (paged.median_s - flat.median_s) / flat.median_s;
        println!("paged {:.1} tok/s vs flat {:.1} tok/s \
                  (overhead {:+.1}%)", paged.tokens_per_s,
                 flat.tokens_per_s, overhead * 100.0);
        report.metric("kv/page/paged_tokens_per_s", paged.tokens_per_s);
        report.metric("kv/page/flat_tokens_per_s", flat.tokens_per_s);
        report.metric("kv/page/overhead_frac", overhead);
    }
    kv_dtype_ablation(&mut report, "tiny_gqa", Layout::helix(2, 2, 4, 1));
    restore_bandwidth(&mut report, "tiny_gqa", Layout::helix(2, 2, 4, 1));
    recovery_replay(&mut report, "tiny_gqa", Layout::helix(2, 2, 4, 1));
    prefill_ingestion(&mut report, "tiny_gqa", Layout::helix(2, 2, 4, 1));

    context_scaling(&mut report, "tiny_gqa",
                    Layout::helix(2, 2, 4, 1));
    report.note("status", "ok");
    write_report(&report);
}
