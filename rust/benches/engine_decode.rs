//! Engine end-to-end microbench: real decode-step latency per model and
//! layout over the AOT artifacts (requires `make artifacts`).
//!
//! This is the measured counterpart of the simulator's TTL: it times the
//! full L3 path (broadcast -> redundant QKV -> round-robin append ->
//! flash-decode -> All-to-All + combine -> TP out-proj -> FFN grid) on
//! the PJRT CPU client, plus the HOP-B overlap comparison under an
//! emulated NVLink.

use helix::engine::{ClusterConfig, CommModel, HelixCluster};
use helix::runtime::artifacts::EngineLayout;
use helix::runtime::Manifest;
use helix::util::bench::bench;

fn step_bench(name: &str, model: &str, layout: EngineLayout, hopb: bool,
              a2a_bw: f64) {
    let mut cc = ClusterConfig::new(model, layout);
    cc.hopb = hopb;
    if a2a_bw > 0.0 {
        // Slow down only the KVP All-to-All (the collective HOP-B
        // pipelines), bandwidth-dominated so overlap is observable.
        cc.a2a_comm = Some(CommModel { latency_s: 0.0,
                                       bw_bytes_per_s: a2a_bw, scale: 1.0 });
    }
    let mut cluster = match HelixCluster::new(cc) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping {name}: {e:#}");
            return;
        }
    };
    for s in 0..cluster.batch() {
        cluster.open_slot(s).unwrap();
    }
    let tokens: Vec<i32> = (0..cluster.batch() as i32).map(|i| i + 3)
        .collect();
    bench(name, 3, 10, || {
        // Steps accumulate context, so later samples attend over more
        // KV — representative of steady-state decode.
        let (next, _) = cluster.decode_step(&tokens).unwrap();
        std::hint::black_box(next);
    });
    cluster.shutdown();
}

fn main() {
    if Manifest::load(&Manifest::default_root()).is_err() {
        eprintln!("artifacts missing — run `make artifacts` first; \
                   skipping engine benches");
        return;
    }
    println!("## engine decode-step latency (real PJRT execution)");
    step_bench("engine/tiny_gqa/helix_kvp2_tpa2", "tiny_gqa",
               EngineLayout { kvp: 2, tpa: 2, tpf: 4, ep: 1 }, false, 0.0);
    step_bench("engine/tiny_gqa/pure_kvp4", "tiny_gqa",
               EngineLayout { kvp: 4, tpa: 1, tpf: 4, ep: 1 }, false, 0.0);
    step_bench("engine/tiny_gqa/tp4", "tiny_gqa",
               EngineLayout { kvp: 1, tpa: 4, tpf: 4, ep: 1 }, false, 0.0);
    step_bench("engine/tiny_gqa/single_rank", "tiny_gqa",
               EngineLayout { kvp: 1, tpa: 1, tpf: 1, ep: 1 }, false, 0.0);
    step_bench("engine/tiny_mla/pure_kvp4", "tiny_mla",
               EngineLayout { kvp: 4, tpa: 1, tpf: 4, ep: 1 }, false, 0.0);
    step_bench("engine/tiny_moe/tpf2_ep2", "tiny_moe",
               EngineLayout { kvp: 2, tpa: 2, tpf: 2, ep: 2 }, false, 0.0);

    println!("\n## HOP-B under an emulated slow All-to-All link");
    step_bench("engine/tiny_gqa/a2a_hopb_off", "tiny_gqa",
               EngineLayout { kvp: 2, tpa: 2, tpf: 4, ep: 1 }, false, 2.0e4);
    step_bench("engine/tiny_gqa/a2a_hopb_on", "tiny_gqa",
               EngineLayout { kvp: 2, tpa: 2, tpf: 4, ep: 1 }, true, 2.0e4);
}
