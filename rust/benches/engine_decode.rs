//! Engine end-to-end microbench: real decode-step latency per model and
//! layout over the AOT artifacts (requires `make artifacts`).
//!
//! This is the measured counterpart of the simulator's TTL: it times the
//! full L3 path (broadcast -> redundant QKV -> round-robin append ->
//! flash-decode -> All-to-All + combine -> TP out-proj -> FFN grid) on
//! the PJRT CPU client, plus the HOP-B overlap comparison under an
//! emulated NVLink.
//!
//! Besides the stdout report it writes `BENCH_engine.json` (tokens/s,
//! per-phase ns, allocations per step) into `$BENCH_OUT` (default: the
//! working directory) — the machine-readable perf trajectory this repo
//! diffs across PRs.

use helix::engine::{ClusterConfig, CommModel, HelixCluster};
use helix::runtime::artifacts::EngineLayout;
use helix::runtime::Manifest;
use helix::util::bench::{alloc_count, bench, CountingAlloc, JsonReport};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn step_bench(report: &mut JsonReport, name: &str, model: &str,
              layout: EngineLayout, hopb: bool, a2a_bw: f64) {
    let mut cc = ClusterConfig::new(model, layout);
    cc.hopb = hopb;
    if a2a_bw > 0.0 {
        // Slow down only the KVP All-to-All (the collective HOP-B
        // pipelines), bandwidth-dominated so overlap is observable.
        cc.a2a_comm = Some(CommModel { latency_s: 0.0,
                                       bw_bytes_per_s: a2a_bw, scale: 1.0 });
    }
    let mut cluster = match HelixCluster::new(cc) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping {name}: {e:#}");
            return;
        }
    };
    for s in 0..cluster.batch() {
        cluster.open_slot(s).unwrap();
    }
    let tokens: Vec<i32> = (0..cluster.batch() as i32).map(|i| i + 3)
        .collect();
    let batch = cluster.batch() as f64;
    const WARMUP: u64 = 3;
    const SAMPLES: usize = 10;
    // Per-phase seconds + allocations over the measured samples only
    // (warmup steps run on a near-empty KV cache and would skew the
    // per-step averages the JSON report diffs across PRs).
    let mut phases = [0.0f64; 3];
    let mut steps = 0u64;
    let mut calls = 0u64;
    // Alloc window bounds captured inside the closure, symmetric around
    // the measured samples only — harness bookkeeping (Measurement
    // construction, report formatting) stays outside the window.
    let (mut a0, mut a1) = (0u64, 0u64);
    let m = bench(name, WARMUP as usize, SAMPLES, || {
        // Steps accumulate context, so later samples attend over more
        // KV — representative of steady-state decode.
        if calls == WARMUP {
            a0 = alloc_count();
        }
        let (next, sm) = cluster.decode_step(&tokens).unwrap();
        if calls >= WARMUP {
            phases[0] += sm.attn.as_secs_f64();
            phases[1] += sm.comm.as_secs_f64();
            phases[2] += sm.ffn.as_secs_f64();
            steps += 1;
        }
        calls += 1;
        if calls == WARMUP + SAMPLES as u64 {
            a1 = alloc_count();
        }
        std::hint::black_box(next);
    });
    let allocs_per_step = (a1 - a0) as f64 / steps as f64;
    report.add(&m);
    report.metric(&format!("{name}/tokens_per_s"), batch / m.median());
    report.metric(&format!("{name}/attn_ns_per_step"),
                  phases[0] / steps as f64 * 1e9);
    report.metric(&format!("{name}/comm_ns_per_step"),
                  phases[1] / steps as f64 * 1e9);
    report.metric(&format!("{name}/ffn_ns_per_step"),
                  phases[2] / steps as f64 * 1e9);
    report.metric(&format!("{name}/allocs_per_step"), allocs_per_step);
    cluster.shutdown();
}

fn write_report(report: &JsonReport) {
    let dir = std::env::var("BENCH_OUT").unwrap_or_else(|_| ".".to_string());
    match report.write(std::path::Path::new(&dir)) {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("bench report write failed: {e:#}"),
    }
}

fn main() {
    let mut report = JsonReport::new("engine");
    if Manifest::load(&Manifest::default_root()).is_err() {
        eprintln!("artifacts missing — run `make artifacts` first; \
                   skipping engine benches");
        report.note("status", "skipped: artifacts missing");
        write_report(&report);
        return;
    }
    println!("## engine decode-step latency (real PJRT execution)");
    step_bench(&mut report, "engine/tiny_gqa/helix_kvp2_tpa2", "tiny_gqa",
               EngineLayout { kvp: 2, tpa: 2, tpf: 4, ep: 1 }, false, 0.0);
    step_bench(&mut report, "engine/tiny_gqa/pure_kvp4", "tiny_gqa",
               EngineLayout { kvp: 4, tpa: 1, tpf: 4, ep: 1 }, false, 0.0);
    step_bench(&mut report, "engine/tiny_gqa/tp4", "tiny_gqa",
               EngineLayout { kvp: 1, tpa: 4, tpf: 4, ep: 1 }, false, 0.0);
    step_bench(&mut report, "engine/tiny_gqa/single_rank", "tiny_gqa",
               EngineLayout { kvp: 1, tpa: 1, tpf: 1, ep: 1 }, false, 0.0);
    step_bench(&mut report, "engine/tiny_mla/pure_kvp4", "tiny_mla",
               EngineLayout { kvp: 4, tpa: 1, tpf: 4, ep: 1 }, false, 0.0);
    step_bench(&mut report, "engine/tiny_moe/tpf2_ep2", "tiny_moe",
               EngineLayout { kvp: 2, tpa: 2, tpf: 2, ep: 2 }, false, 0.0);

    println!("\n## HOP-B under an emulated slow All-to-All link");
    step_bench(&mut report, "engine/tiny_gqa/a2a_hopb_off", "tiny_gqa",
               EngineLayout { kvp: 2, tpa: 2, tpf: 4, ep: 1 }, false, 2.0e4);
    step_bench(&mut report, "engine/tiny_gqa/a2a_hopb_on", "tiny_gqa",
               EngineLayout { kvp: 2, tpa: 2, tpf: 4, ep: 1 }, true, 2.0e4);
    report.note("status", "ok");
    write_report(&report);
}
