#!/usr/bin/env python3
"""Gate a measured-throughput report against a checked-in baseline.

Usage:
    python3 scripts/check_bench_regression.py CURRENT BASELINE [--threshold T]

Two report schemas are understood:

* ``BENCH_engine.json`` (``cargo bench --bench engine_decode``): every
  ``*/tokens_per_s`` entry under ``metrics``.
* ``BENCH_pareto.json`` (``helix eval``, ``kind: "helix-eval"``): one
  ``pareto/<model>/<layout>/tokens_per_step_per_gpu`` metric per
  evaluated plan. The *step-normalized* throughput is gated on purpose:
  it is bit-deterministic on the native backend, so any regression it
  reports is a real scheduling/admission change, not CI wall-clock
  noise.

Fails (exit 1) if any metric present in both reports regresses by more
than T (default 0.10 = 10%), or if baseline metrics vanished from the
current run. A missing baseline is not a failure: the first measured
run prints its numbers and asks for the baseline to be committed — that
run *is* the baseline. A current report whose status says "skipped"
fails: with the native backend the bench must always execute.

The engine report's HOP-B overlap ablation is gated on its *exposed
communication fractions* (``overlap/a2a/exposed_frac_{off,on}``): the
pipelined schedule must expose measurably less of the modeled link time
than lockstep does. The fraction is built from modeled link charges and
requested rank waits, so it is far less wall-clock-noisy than the raw
step speedup (which is printed for information only, never gated).

The paged-KV ablation (``kv/page/*``) is gated on its *overhead
fraction*: page-table indirection may cost at most PAGED_MAX_OVERHEAD
(5%) of flat-arena decode throughput. It is a ratio of two medians from
the same run, so machine speed cancels out. The restore bandwidth
(``kv/page/restore_gb_s_per_rank``) is printed for information only —
host-tier copy speed is machine-dependent.

The KV storage dtype ablation (``kv/dtype/*``) checks within-run that
bytes/token shrinks monotonically across f32 -> f16 -> int8, and gates
the f32 row's long-context attention time against the baseline (10%
drift, like tokens/s). The f16/int8 rows are printed for information
only while the quantized tier settles (docs/QUANTKV.md).

The chunked-prefill section (``prefill/*``) is gated within-run: the
ingestion rate (``prefill/<model>/chunk_tokens_per_s``) must be
positive, and the TTFT trajectory (``prefill/<model>/ttft_ctx<N>_ms``)
must be monotone non-decreasing in context length — cumulative
ingestion time can only grow with the prefix. Absolute TTFT numbers are
machine-dependent and never gated across runs.

Stdlib only (the CI runner needs nothing installed).
"""

import argparse
import json
import os
import sys


def layout_key(layout: dict) -> str:
    key = "kvp{kvp}_tpa{tpa}_tpf{tpf}_ep{ep}".format(
        **{k: layout.get(k, "?") for k in ("kvp", "tpa", "tpf", "ep")})
    if layout.get("pp", 1) > 1:
        key += f"_pp{layout['pp']}"
    return key


def eval_metrics(report: dict) -> dict:
    """``helix eval`` documents: the deterministic per-plan throughput."""
    out = {}
    for entry in report.get("models") or []:
        model = entry.get("model", "?")
        for plan in entry.get("plans") or []:
            measured = plan.get("measured") or {}
            v = measured.get("tokens_per_step_per_gpu")
            if isinstance(v, (int, float)):
                key = f"pareto/{model}/{layout_key(plan.get('layout', {}))}"
                out[f"{key}/tokens_per_step_per_gpu"] = v
    return out


def tokens_metrics(report: dict) -> dict:
    if report.get("kind") == "helix-eval" or "models" in report:
        return eval_metrics(report)
    return {k: v for k, v in report.get("metrics", {}).items()
            if k.endswith("/tokens_per_s") and isinstance(v, (int, float))}


# The pipeline must hide at least this much of the link time relative
# to lockstep (absolute drop in exposed fraction)...
OVERLAP_MIN_DROP = 0.05
# ...and may not drift this far above its own committed baseline.
OVERLAP_DRIFT = 0.15


def overlap_failures(cur, base):
    """Engine-report overlap gate; no-op for reports without the
    ablation (eval reports, older baselines)."""
    metrics = cur.get("metrics", {})
    off = metrics.get("overlap/a2a/exposed_frac_off")
    on = metrics.get("overlap/a2a/exposed_frac_on")
    if not isinstance(off, (int, float)) or not isinstance(on, (int, float)):
        return []
    failures = []
    speedup = metrics.get("overlap/a2a/step_speedup")
    extra = (f", step speedup x{speedup:.2f} (informational)"
             if isinstance(speedup, (int, float)) else "")
    print(f"overlap: exposed comm fraction {off:.3f} (lockstep) -> "
          f"{on:.3f} (HOP-B){extra}")
    if on >= off - OVERLAP_MIN_DROP:
        failures.append(
            f"HOP-B overlap gone: exposed_frac_on={on:.3f} is not at "
            f"least {OVERLAP_MIN_DROP} below exposed_frac_off={off:.3f}")
    base_on = (base or {}).get("metrics", {}).get(
        "overlap/a2a/exposed_frac_on")
    if isinstance(base_on, (int, float)) and on > base_on + OVERLAP_DRIFT:
        failures.append(
            f"exposed_frac_on drifted: {base_on:.3f} (baseline) -> "
            f"{on:.3f} (now), tolerance +{OVERLAP_DRIFT}")
    return failures


# Page-table indirection may slow the decode step by at most this
# fraction relative to flat dense arenas (a within-run ratio, so it is
# immune to machine-speed differences between runs).
PAGED_MAX_OVERHEAD = 0.05


def paged_failures(cur):
    """Engine-report paged-KV gate; no-op for reports without the
    ablation (eval reports, older baselines)."""
    metrics = cur.get("metrics", {})
    overhead = metrics.get("kv/page/overhead_frac")
    if not isinstance(overhead, (int, float)):
        return []
    gbs = metrics.get("kv/page/restore_gb_s_per_rank")
    extra = (f", restore {gbs:.3f} GB/s per rank (informational)"
             if isinstance(gbs, (int, float)) else "")
    print(f"paged KV: overhead {overhead:+.1%} vs flat arenas{extra}")
    if overhead > PAGED_MAX_OVERHEAD:
        return [f"paged KV overhead {overhead:.1%} exceeds the "
                f"{PAGED_MAX_OVERHEAD:.0%} budget over flat arenas"]
    return []


# The f32 row of the KV-dtype ablation may not get slower than its
# committed baseline by more than this fraction (same 10% grace the
# tokens/s gate uses; the quantized rows are report-only while the
# tier settles — see docs/QUANTKV.md).
KV_DTYPE_F32_DRIFT = 0.10


def kv_dtype_failures(cur, base):
    """Engine-report KV storage dtype gate; no-op for reports without
    the ablation (eval reports, older baselines)."""
    metrics = cur.get("metrics", {})
    rows = []
    for dtype in ("f32", "f16", "int8"):
        bpt = metrics.get(f"kv/dtype/{dtype}/bytes_per_token")
        ns = metrics.get(f"kv/dtype/{dtype}/attn_ns_longctx")
        if isinstance(bpt, (int, float)) and isinstance(ns, (int, float)):
            rows.append((dtype, bpt, ns))
    if not rows:
        return []
    for dtype, bpt, ns in rows:
        extra = "" if dtype == "f32" else " (informational)"
        print(f"kv dtype {dtype}: {bpt:.1f} bytes/token, attn "
              f"{ns:.0f} ns/step at long context{extra}")
    failures = []
    # Within-run: the footprint must actually shrink with the dtype.
    by = {d: bpt for d, bpt, _ in rows}
    order = [by[d] for d in ("f32", "f16", "int8") if d in by]
    if order != sorted(order, reverse=True):
        failures.append(
            "kv/dtype bytes_per_token not decreasing across "
            f"f32 -> f16 -> int8: {by}")
    # Cross-run: the f32 tier (the default path every PR exercises) may
    # not quietly get slower at long context.
    cur_ns = metrics.get("kv/dtype/f32/attn_ns_longctx")
    base_ns = (base or {}).get("metrics", {}).get(
        "kv/dtype/f32/attn_ns_longctx")
    if (isinstance(cur_ns, (int, float)) and
            isinstance(base_ns, (int, float)) and
            cur_ns > base_ns * (1 + KV_DTYPE_F32_DRIFT)):
        failures.append(
            f"kv/dtype/f32/attn_ns_longctx regressed: {base_ns:.0f} ns "
            f"(baseline) -> {cur_ns:.0f} ns (now), tolerance "
            f"+{KV_DTYPE_F32_DRIFT:.0%}")
    return failures


def prefill_failures(cur):
    """Engine-report chunked-prefill gate; no-op for reports without
    the section (eval reports, older baselines)."""
    metrics = cur.get("metrics", {})
    rates = {k: v for k, v in metrics.items()
             if k.startswith("prefill/") and
             k.endswith("/chunk_tokens_per_s")}
    if not rates:
        return []
    failures = []
    for key, rate in sorted(rates.items()):
        model = key.split("/")[1]
        if not isinstance(rate, (int, float)) or rate <= 0:
            failures.append(f"{key} is not positive ({rate!r})")
            continue
        prefix = f"prefill/{model}/ttft_ctx"
        ttfts = sorted(
            (int(k[len(prefix):-len("_ms")]), v)
            for k, v in metrics.items()
            if k.startswith(prefix) and k.endswith("_ms"))
        pts = ", ".join(f"{c}:{v:.2f}ms" for c, v in ttfts)
        print(f"prefill {model}: {rate:.0f} tok/s ingested, TTFT [{pts}]")
        if len(ttfts) < 2:
            failures.append(
                f"prefill/{model}: TTFT-vs-context sweep missing "
                f"(got {len(ttfts)} points)")
        for (c0, t0), (c1, t1) in zip(ttfts, ttfts[1:]):
            if t1 < t0:
                failures.append(
                    f"prefill/{model}: TTFT not monotone in context "
                    f"(ctx {c0}: {t0:.3f} ms > ctx {c1}: {t1:.3f} ms)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--threshold", type=float, default=0.10)
    args = ap.parse_args(argv)

    with open(args.current) as f:
        cur = json.load(f)
    status = cur.get("metrics", {}).get("status", "")
    if str(status).startswith("skipped"):
        print(f"FAIL: bench did not execute (status={status!r}); the "
              f"native backend must always run the engine bench")
        return 1
    cur_tok = tokens_metrics(cur)
    if not cur_tok:
        print("FAIL: no throughput metrics in the current report")
        return 1

    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline} — recording run "
              f"(commit the current report there to start gating):")
        for k in sorted(cur_tok):
            print(f"  {k}: {cur_tok[k]:.3f}")
        # The within-report overlap, paged-KV and prefill contracts
        # hold even on a first run.
        within = (overlap_failures(cur, None) + paged_failures(cur)
                  + kv_dtype_failures(cur, None) + prefill_failures(cur))
        if within:
            print("FAIL: " + "; ".join(within))
            return 1
        return 0

    with open(args.baseline) as f:
        base = json.load(f)
    base_tok = tokens_metrics(base)

    failures, lines = [], []
    for k in sorted(set(cur_tok) & set(base_tok)):
        c, b = cur_tok[k], base_tok[k]
        delta = (c - b) / b if b else 0.0
        mark = "ok"
        if delta < -args.threshold:
            mark = "REGRESSION"
            failures.append(k)
        lines.append(f"  {k}: {b:.3f} -> {c:.3f} ({delta:+.1%}) {mark}")
    print(f"tokens/s vs baseline (threshold -{args.threshold:.0%}):")
    print("\n".join(lines) if lines else "  (no overlapping metrics)")

    only_base = sorted(set(base_tok) - set(cur_tok))
    if only_base:
        print("FAIL: baseline metrics missing from the current run "
              "(bench coverage shrank): " + ", ".join(only_base))
        return 1
    if failures:
        print(f"FAIL: {len(failures)} tokens/s regression(s) > "
              f"{args.threshold:.0%}")
        return 1
    within = (overlap_failures(cur, base) + paged_failures(cur)
              + kv_dtype_failures(cur, base) + prefill_failures(cur))
    if within:
        print("FAIL: " + "; ".join(within))
        return 1
    print("bench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
