#!/usr/bin/env python3
"""Gate BENCH_engine.json against the checked-in baseline.

Usage:
    python3 scripts/check_bench_regression.py CURRENT BASELINE [--threshold T]

Compares every ``*/tokens_per_s`` metric present in both reports and
fails (exit 1) if any regresses by more than T (default 0.10 = 10%).
A missing baseline is not a failure: the first measured run prints its
numbers and asks for the baseline to be committed — that run *is* the
baseline. A current report whose status says "skipped" fails: with the
native backend the engine bench must always execute.

Stdlib only (the CI runner needs nothing installed).
"""

import argparse
import json
import os
import sys


def tokens_metrics(report: dict) -> dict:
    return {k: v for k, v in report.get("metrics", {}).items()
            if k.endswith("/tokens_per_s") and isinstance(v, (int, float))}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--threshold", type=float, default=0.10)
    args = ap.parse_args()

    with open(args.current) as f:
        cur = json.load(f)
    status = cur.get("metrics", {}).get("status", "")
    if str(status).startswith("skipped"):
        print(f"FAIL: bench did not execute (status={status!r}); the "
              f"native backend must always run the engine bench")
        return 1
    cur_tok = tokens_metrics(cur)
    if not cur_tok:
        print("FAIL: no */tokens_per_s metrics in the current report")
        return 1

    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline} — recording run "
              f"(commit the current report there to start gating):")
        for k in sorted(cur_tok):
            print(f"  {k}: {cur_tok[k]:.3f}")
        return 0

    with open(args.baseline) as f:
        base = json.load(f)
    base_tok = tokens_metrics(base)

    failures, lines = [], []
    for k in sorted(set(cur_tok) & set(base_tok)):
        c, b = cur_tok[k], base_tok[k]
        delta = (c - b) / b if b else 0.0
        mark = "ok"
        if delta < -args.threshold:
            mark = "REGRESSION"
            failures.append(k)
        lines.append(f"  {k}: {b:.3f} -> {c:.3f} ({delta:+.1%}) {mark}")
    print(f"tokens/s vs baseline (threshold -{args.threshold:.0%}):")
    print("\n".join(lines) if lines else "  (no overlapping metrics)")

    only_base = sorted(set(base_tok) - set(cur_tok))
    if only_base:
        print("FAIL: baseline metrics missing from the current run "
              "(bench coverage shrank): " + ", ".join(only_base))
        return 1
    if failures:
        print(f"FAIL: {len(failures)} tokens/s regression(s) > "
              f"{args.threshold:.0%}")
        return 1
    print("bench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
