#!/usr/bin/env python3
"""Smoke tests for the measured/overlay JSON schema in
``plot_pareto.py`` and ``check_bench_regression.py``, driven by the
checked-in fixture ``benchmarks/BENCH_pareto.fixture.json``.

Stdlib-only (unittest), matching the scripts under test:

    python3 scripts/test_plot_pareto.py
"""

import copy
import json
import os
import sys
import tempfile
import unittest

SCRIPTS = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(SCRIPTS)
FIXTURE = os.path.join(ROOT, "benchmarks", "BENCH_pareto.fixture.json")
sys.path.insert(0, SCRIPTS)

import check_bench_regression  # noqa: E402
import plot_pareto  # noqa: E402


class LoadTest(unittest.TestCase):
    def test_eval_doc_selects_first_model_by_default(self):
        meta, frontiers = plot_pareto.load(FIXTURE)
        self.assertEqual(meta["model"], "tiny_gqa")
        self.assertEqual(meta["kind"], "helix-eval")
        self.assertEqual(len(frontiers["predicted"]), 1)
        self.assertEqual(len(frontiers["measured"]), 2)

    def test_eval_doc_model_selection(self):
        meta, frontiers = plot_pareto.load(FIXTURE, model="tiny_moe")
        self.assertEqual(meta["model"], "tiny_moe")
        self.assertEqual(len(frontiers["measured"]), 1)
        with self.assertRaises(SystemExit):
            plot_pareto.load(FIXTURE, model="no_such_model")

    def test_legacy_plan_doc_still_loads(self):
        doc = {"model": "deepseek-r1", "ttl_budget_ms": 5.0,
               "frontiers": {
                   "baseline": [{"tok_s_user": 10.0, "tok_s_gpu": 1.0}],
                   "helix": [{"tok_s_user": 15.0, "tok_s_gpu": 2.0}]}}
        with tempfile.NamedTemporaryFile("w", suffix=".json",
                                         delete=False) as f:
            json.dump(doc, f)
            path = f.name
        try:
            meta, frontiers = plot_pareto.load(path)
            self.assertEqual(meta["model"], "deepseek-r1")
            self.assertIn("helix", frontiers)
        finally:
            os.unlink(path)


class SeriesTest(unittest.TestCase):
    def test_overlay_series_and_predicted_normalization(self):
        _, frontiers = plot_pareto.load(FIXTURE)
        series = plot_pareto.normalized_series(frontiers)
        labels = [label for label, _, _, _ in series]
        self.assertIn("predicted (planner sweep)", labels)
        self.assertIn("measured (served traces)", labels)
        # No baseline in eval docs: normalized to the predicted maxima,
        # so the predicted series touches 1.0 on both axes.
        pred = next(s for s in series if s[0].startswith("predicted"))
        xs = [x for x, _ in pred[3]]
        ys = [y for _, y in pred[3]]
        self.assertAlmostEqual(max(xs), 1.0)
        self.assertAlmostEqual(max(ys), 1.0)
        # Measured points normalize on the same scale (far below 1).
        meas = next(s for s in series if s[0].startswith("measured"))
        self.assertTrue(all(x < 1.0 and y < 1.0 for x, y in meas[3]))

    def test_baseline_normalization_when_present(self):
        frontiers = {"baseline": [{"tok_s_user": 10.0, "tok_s_gpu": 2.0}],
                     "helix": [{"tok_s_user": 20.0, "tok_s_gpu": 4.0}]}
        series = plot_pareto.normalized_series(frontiers)
        helix = next(s for s in series if s[0] == "helix")
        self.assertEqual(helix[3], [(2.0, 2.0)])


class SvgRenderTest(unittest.TestCase):
    def render(self, meta, frontiers):
        series = plot_pareto.normalized_series(frontiers)
        with tempfile.NamedTemporaryFile("r", suffix=".svg",
                                         delete=False) as f:
            path = f.name
        try:
            plot_pareto.plot_svg(meta, series, path)
            with open(path) as f:
                return f.read()
        finally:
            os.unlink(path)

    def test_svg_fallback_renders_the_overlay(self):
        meta, frontiers = plot_pareto.load(FIXTURE)
        svg = self.render(meta, frontiers)
        self.assertIn("<svg", svg)
        self.assertIn("measured (served traces)", svg)
        self.assertIn("predicted (planner sweep)", svg)
        self.assertIn("stroke-dasharray", svg)  # measured guide line
        self.assertIn("tiny_gqa", svg)

    def test_svg_fallback_handles_single_point_series(self):
        # A one-plan eval (degenerate axis span) must still render.
        meta, frontiers = plot_pareto.load(FIXTURE, model="tiny_moe")
        svg = self.render(meta, frontiers)
        self.assertIn("<svg", svg)
        self.assertIn("tiny_moe", svg)


class TtftAxisTest(unittest.TestCase):
    """Schema-v3 TTFT-vs-context series extraction and rendering."""

    def test_load_carries_ttft_series(self):
        meta, _ = plot_pareto.load(FIXTURE)
        self.assertEqual(len(meta["ttft_vs_context"]), 2)

    def test_series_sorted_and_empty_dropped(self):
        meta, _ = plot_pareto.load(FIXTURE)
        series = plot_pareto.ttft_series(meta)
        # The kvp1 plan's series has no points and must be dropped.
        self.assertEqual(len(series), 1)
        label, pts = series[0]
        self.assertIn("kvp2_tpa2_tpf4_ep1", label)
        self.assertEqual(pts, [(9.0, 6.5), (34.0, 14.0)])

    def test_legacy_plan_docs_have_no_ttft_axis(self):
        self.assertEqual(plot_pareto.ttft_series({"model": "x"}), [])

    def test_ttft_svg_renders(self):
        meta, _ = plot_pareto.load(FIXTURE)
        series = plot_pareto.ttft_series(meta)
        with tempfile.NamedTemporaryFile("r", suffix=".svg",
                                         delete=False) as f:
            path = f.name
        try:
            plot_pareto.ttft_svg(meta, series, path)
            with open(path) as f:
                svg = f.read()
        finally:
            os.unlink(path)
        self.assertIn("<svg", svg)
        self.assertIn("TTFT vs context length", svg)
        self.assertIn("context length (tokens)", svg)
        self.assertIn("kvp2_tpa2_tpf4_ep1", svg)

    def test_single_point_series_renders(self):
        meta, _ = plot_pareto.load(FIXTURE, model="tiny_moe")
        series = plot_pareto.ttft_series(meta)
        self.assertEqual(len(series), 1)
        with tempfile.NamedTemporaryFile("r", suffix=".svg",
                                         delete=False) as f:
            path = f.name
        try:
            plot_pareto.ttft_svg(meta, series, path)
            with open(path) as f:
                svg = f.read()
        finally:
            os.unlink(path)
        self.assertIn("<svg", svg)
        self.assertIn("tiny_moe", svg)


class RegressionGateTest(unittest.TestCase):
    def setUp(self):
        with open(FIXTURE) as f:
            self.doc = json.load(f)

    def write(self, doc):
        f = tempfile.NamedTemporaryFile("w", suffix=".json", delete=False)
        json.dump(doc, f)
        f.close()
        self.addCleanup(os.unlink, f.name)
        return f.name

    def test_eval_metrics_extraction(self):
        metrics = check_bench_regression.tokens_metrics(self.doc)
        self.assertEqual(metrics, {
            "pareto/tiny_gqa/kvp2_tpa2_tpf4_ep1/tokens_per_step_per_gpu":
                0.2,
            "pareto/tiny_gqa/kvp1_tpa1_tpf1_ep1/tokens_per_step_per_gpu":
                0.15,
            "pareto/tiny_moe/kvp2_tpa2_tpf2_ep2/tokens_per_step_per_gpu":
                0.18,
        })

    def test_engine_schema_still_extracts(self):
        report = {"metrics": {"decode/tokens_per_s": 123.0,
                              "decode/phase_ns": 9.0, "status": "ok"}}
        self.assertEqual(check_bench_regression.tokens_metrics(report),
                         {"decode/tokens_per_s": 123.0})

    def test_identical_reports_pass(self):
        path = self.write(self.doc)
        self.assertEqual(check_bench_regression.main([path, path]), 0)

    def test_missing_baseline_records_not_fails(self):
        path = self.write(self.doc)
        self.assertEqual(
            check_bench_regression.main([path, path + ".does_not_exist"]),
            0)

    def test_regression_fails_the_gate(self):
        worse = copy.deepcopy(self.doc)
        m = worse["models"][0]["plans"][0]["measured"]
        m["tokens_per_step_per_gpu"] *= 0.5  # -50% < -10% threshold
        cur = self.write(worse)
        base = self.write(self.doc)
        self.assertEqual(check_bench_regression.main([cur, base]), 1)

    def test_vanished_plan_fails_the_gate(self):
        shrunk = copy.deepcopy(self.doc)
        shrunk["models"][0]["plans"].pop()
        cur = self.write(shrunk)
        base = self.write(self.doc)
        self.assertEqual(check_bench_regression.main([cur, base]), 1)


class OverlapGateTest(unittest.TestCase):
    """The engine report's HOP-B exposed-comm-fraction contract."""

    def engine_report(self, off=0.95, on=0.40):
        return {"metrics": {"engine/tiny/tokens_per_s": 100.0,
                            "overlap/a2a/exposed_frac_off": off,
                            "overlap/a2a/exposed_frac_on": on,
                            "overlap/a2a/step_speedup": 1.5,
                            "status": "ok"}}

    def write(self, doc):
        f = tempfile.NamedTemporaryFile("w", suffix=".json", delete=False)
        json.dump(doc, f)
        f.close()
        self.addCleanup(os.unlink, f.name)
        return f.name

    def test_healthy_overlap_passes(self):
        self.assertEqual(
            check_bench_regression.overlap_failures(self.engine_report(),
                                                    None), [])
        path = self.write(self.engine_report())
        self.assertEqual(check_bench_regression.main([path, path]), 0)

    def test_lost_overlap_fails_even_without_baseline(self):
        # on ~ off: the pipeline hid nothing.
        broken = self.engine_report(off=0.95, on=0.93)
        self.assertTrue(
            check_bench_regression.overlap_failures(broken, None))
        cur = self.write(broken)
        self.assertEqual(
            check_bench_regression.main([cur, cur + ".missing"]), 1)

    def test_exposed_fraction_drift_vs_baseline_fails(self):
        base = self.engine_report(on=0.30)
        cur = self.engine_report(on=0.30 + check_bench_regression
                                 .OVERLAP_DRIFT + 0.05)
        self.assertTrue(
            check_bench_regression.overlap_failures(cur, base))
        self.assertEqual(
            check_bench_regression.main([self.write(cur),
                                         self.write(base)]), 1)

    def test_reports_without_ablation_are_not_gated(self):
        report = {"metrics": {"decode/tokens_per_s": 1.0, "status": "ok"}}
        self.assertEqual(
            check_bench_regression.overlap_failures(report, None), [])


class PagedGateTest(unittest.TestCase):
    """The engine report's paged-KV overhead contract."""

    def engine_report(self, overhead=0.01):
        return {"metrics": {"engine/tiny/tokens_per_s": 100.0,
                            "kv/page/paged_tokens_per_s": 100.0,
                            "kv/page/flat_tokens_per_s": 101.0,
                            "kv/page/overhead_frac": overhead,
                            "kv/page/restore_gb_s_per_rank": 2.5,
                            "status": "ok"}}

    def write(self, doc):
        f = tempfile.NamedTemporaryFile("w", suffix=".json", delete=False)
        json.dump(doc, f)
        f.close()
        self.addCleanup(os.unlink, f.name)
        return f.name

    def test_cheap_indirection_passes(self):
        self.assertEqual(
            check_bench_regression.paged_failures(self.engine_report()), [])
        path = self.write(self.engine_report())
        self.assertEqual(check_bench_regression.main([path, path]), 0)

    def test_negative_overhead_passes(self):
        # Paged faster than flat (cache effects) is fine.
        self.assertEqual(check_bench_regression.paged_failures(
            self.engine_report(overhead=-0.02)), [])

    def test_expensive_indirection_fails_even_without_baseline(self):
        broken = self.engine_report(
            overhead=check_bench_regression.PAGED_MAX_OVERHEAD + 0.02)
        self.assertTrue(check_bench_regression.paged_failures(broken))
        cur = self.write(broken)
        self.assertEqual(
            check_bench_regression.main([cur, cur + ".missing"]), 1)
        # ... and with a baseline present.
        self.assertEqual(check_bench_regression.main([cur, cur]), 1)

    def test_reports_without_ablation_are_not_gated(self):
        report = {"metrics": {"decode/tokens_per_s": 1.0, "status": "ok"}}
        self.assertEqual(
            check_bench_regression.paged_failures(report), [])


class KvDtypeGateTest(unittest.TestCase):
    """The engine report's KV storage dtype ablation contract."""

    def engine_report(self, f32_ns=5000.0, f16_bpt=512.0):
        return {"metrics": {"engine/tiny/tokens_per_s": 100.0,
                            "kv/dtype/f32/bytes_per_token": 1024.0,
                            "kv/dtype/f32/attn_ns_longctx": f32_ns,
                            "kv/dtype/f16/bytes_per_token": f16_bpt,
                            "kv/dtype/f16/attn_ns_longctx": 9000.0,
                            "kv/dtype/int8/bytes_per_token": 260.0,
                            "kv/dtype/int8/attn_ns_longctx": 8000.0,
                            "status": "ok"}}

    def write(self, doc):
        f = tempfile.NamedTemporaryFile("w", suffix=".json", delete=False)
        json.dump(doc, f)
        f.close()
        self.addCleanup(os.unlink, f.name)
        return f.name

    def test_healthy_ablation_passes(self):
        report = self.engine_report()
        self.assertEqual(
            check_bench_regression.kv_dtype_failures(report, report), [])
        path = self.write(report)
        self.assertEqual(check_bench_regression.main([path, path]), 0)

    def test_f32_regression_fails_vs_baseline(self):
        base = self.engine_report()
        drift = 1 + check_bench_regression.KV_DTYPE_F32_DRIFT
        cur = self.engine_report(f32_ns=5000.0 * drift * 1.1)
        self.assertTrue(
            check_bench_regression.kv_dtype_failures(cur, base))
        self.assertEqual(check_bench_regression.main(
            [self.write(cur), self.write(base)]), 1)

    def test_quantized_rows_are_report_only(self):
        # An arbitrarily slow int8 row never fails the gate.
        base = self.engine_report()
        cur = self.engine_report()
        cur["metrics"]["kv/dtype/int8/attn_ns_longctx"] = 9.9e9
        self.assertEqual(
            check_bench_regression.kv_dtype_failures(cur, base), [])

    def test_nonmonotone_footprint_fails(self):
        broken = self.engine_report(f16_bpt=2048.0)
        self.assertTrue(
            check_bench_regression.kv_dtype_failures(broken, None))
        cur = self.write(broken)
        self.assertEqual(
            check_bench_regression.main([cur, cur + ".missing"]), 1)

    def test_reports_without_ablation_are_not_gated(self):
        report = {"metrics": {"decode/tokens_per_s": 1.0, "status": "ok"}}
        self.assertEqual(
            check_bench_regression.kv_dtype_failures(report, None), [])


class PrefillGateTest(unittest.TestCase):
    """The engine report's chunked-prefill ingestion contract."""

    def engine_report(self, rate=5000.0, ttfts=((31, 2.0), (63, 4.5),
                                                (127, 10.0), (255, 24.0))):
        metrics = {"engine/tiny/tokens_per_s": 100.0,
                   "prefill/tiny_gqa/chunk_tokens_per_s": rate,
                   "status": "ok"}
        for ctx, ms in ttfts:
            metrics[f"prefill/tiny_gqa/ttft_ctx{ctx}_ms"] = ms
        return {"metrics": metrics}

    def write(self, doc):
        f = tempfile.NamedTemporaryFile("w", suffix=".json", delete=False)
        json.dump(doc, f)
        f.close()
        self.addCleanup(os.unlink, f.name)
        return f.name

    def test_healthy_prefill_passes(self):
        self.assertEqual(
            check_bench_regression.prefill_failures(self.engine_report()),
            [])
        path = self.write(self.engine_report())
        self.assertEqual(check_bench_regression.main([path, path]), 0)

    def test_nonpositive_rate_fails(self):
        broken = self.engine_report(rate=0.0)
        self.assertTrue(check_bench_regression.prefill_failures(broken))
        cur = self.write(broken)
        self.assertEqual(
            check_bench_regression.main([cur, cur + ".missing"]), 1)

    def test_nonmonotone_ttft_fails(self):
        # Cumulative ingestion time cannot shrink with more context.
        broken = self.engine_report(ttfts=((31, 2.0), (63, 4.5),
                                           (127, 4.0), (255, 24.0)))
        self.assertTrue(check_bench_regression.prefill_failures(broken))
        cur = self.write(broken)
        self.assertEqual(check_bench_regression.main([cur, cur]), 1)

    def test_missing_ttft_sweep_fails(self):
        broken = self.engine_report(ttfts=((255, 24.0),))
        self.assertTrue(check_bench_regression.prefill_failures(broken))

    def test_reports_without_prefill_are_not_gated(self):
        report = {"metrics": {"decode/tokens_per_s": 1.0, "status": "ok"}}
        self.assertEqual(
            check_bench_regression.prefill_failures(report), [])


if __name__ == "__main__":
    unittest.main(verbosity=2)
