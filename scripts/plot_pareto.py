#!/usr/bin/env python3
"""Render the Fig 5/6-style throughput-vs-interactivity frontier.

Two input schemas are understood:

* ``helix plan --sweep`` documents — predicted ``frontiers.helix`` and
  ``frontiers.baseline`` series (the historical behaviour)::

      cargo run --release -- plan --model deepseek-r1 --sweep --out plan.json
      python3 scripts/plot_pareto.py plan.json [-o pareto.png]

* ``helix eval`` documents (``kind: "helix-eval"``, e.g.
  ``benchmarks/BENCH_pareto.json``) — per-model ``frontiers.predicted``
  and ``frontiers.measured`` series, overlaid on one plot so the
  planner's prediction and the served measurement sit on the same axes
  (``make pareto-measured``)::

      cargo run --release -- eval --out BENCH_pareto.json --smoke
      python3 scripts/plot_pareto.py BENCH_pareto.json [--model tiny_gqa]

With matplotlib installed this writes whatever ``-o``'s suffix says
(default ``<input>.png``); without it, a dependency-free SVG is written
instead (``<input>.svg``). Axes are normalized to the baseline
frontier's maxima when a baseline series exists (exactly as the paper
reports its results, S3.1); eval documents have no baseline sweep, so
they normalize to the predicted series instead.

Schema-v3 eval documents additionally carry a per-model
``ttft_vs_context`` section (one series per evaluated plan, populated
by the ``long_prefill`` scenario's per-request samples). When present,
a second chart — TTFT vs context length — is written next to the
frontier plot as ``<out stem>.ttft.<ext>``.

Stdlib-only by design — matplotlib is optional.
"""

import argparse
import json
import math
import os
import sys

# key -> (label, color, style). "steps" draws the frontier staircase;
# "scatter" draws unconnected measured points (a measurement is a
# sample, not a continuous tradeoff curve).
SERIES = [
    ("baseline", "baseline (best TP/PP/KVP/EP)", "#888888", "steps"),
    ("helix", "helix", "#1f6feb", "steps"),
    ("predicted", "predicted (planner sweep)", "#1f6feb", "steps"),
    ("measured", "measured (served traces)", "#d62728", "scatter"),
]


def load(path, model=None):
    """Return ``(doc_meta, frontiers)`` for either input schema."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("kind") == "helix-eval" or "models" in doc:
        entries = doc.get("models") or []
        if not entries:
            sys.exit(f"{path}: eval document has no models")
        names = [e.get("model", "?") for e in entries]
        if model is None:
            entry = entries[0]
        else:
            matches = [e for e in entries if e.get("model") == model]
            if not matches:
                sys.exit(f"{path}: no model {model!r} (have: "
                         f"{', '.join(names)})")
            entry = matches[0]
        frontiers = entry.get("frontiers")
        if not frontiers:
            sys.exit(f"{path}: model {entry.get('model')!r} has no "
                     f"\"frontiers\" section")
        meta = {"model": entry.get("model", "?"),
                "ttl_budget_ms": None,
                "kind": "helix-eval",
                "ttft_vs_context": entry.get("ttft_vs_context") or []}
        return meta, frontiers
    frontiers = doc.get("frontiers")
    if not frontiers:
        sys.exit(f"{path}: no \"frontiers\" section — regenerate with "
                 f"`helix plan --sweep` or `helix eval`")
    return doc, frontiers


def normalized_series(frontiers):
    """Normalize all series to one reference: baseline when present
    (plan docs), else predicted (eval docs), else the global maxima."""
    ref = frontiers.get("baseline") or frontiers.get("predicted")
    if not ref:
        ref = [p for pts in frontiers.values() for p in (pts or [])]
    ni = max((p["tok_s_user"] for p in ref), default=1.0) or 1.0
    nt = max((p["tok_s_gpu"] for p in ref), default=1.0) or 1.0
    out = []
    for key, label, color, style in SERIES:
        pts = [(p["tok_s_user"] / ni, p["tok_s_gpu"] / nt)
               for p in frontiers.get(key) or []]
        pts.sort()
        if pts:
            out.append((label, color, style, pts))
    if not out:
        sys.exit("frontiers are empty — nothing to plot")
    return out


PALETTE = ["#1f6feb", "#d62728", "#2ca02c", "#9467bd", "#8c564b",
           "#e377c2", "#7f7f7f", "#bcbd22"]


def ttft_series(meta):
    """Schema-v3 TTFT-vs-context series: ``[(label, [(ctx, ms)...])]``,
    contexts ascending, empty-point series dropped."""
    out = []
    for s in meta.get("ttft_vs_context") or []:
        pts = sorted((float(c), float(t)) for c, t in s.get("points") or [])
        if pts:
            out.append((f'{s.get("strategy", "?")} '
                        f'{s.get("layout", "?")}', pts))
    return out


def plot_ttft_matplotlib(meta, series, out):
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    fig, ax = plt.subplots(figsize=(7, 5))
    for i, (label, pts) in enumerate(series):
        xs, ys = zip(*pts)
        ax.plot(xs, ys, marker="o", markersize=4, linewidth=1.2,
                label=label, color=PALETTE[i % len(PALETTE)])
    ax.set_xlabel("context length (tokens)")
    ax.set_ylabel("TTFT (ms)")
    ax.set_title(f"TTFT vs context length — {meta.get('model', '?')}")
    ax.grid(True, alpha=0.3)
    ax.legend()
    fig.tight_layout()
    fig.savefig(out, dpi=150)
    print(f"wrote {out}")


def ttft_svg(meta, series, out):
    """Dependency-free TTFT-vs-context chart: linear axes, one
    polyline+markers per plan."""
    w, h, margin = 720, 520, 60
    xs = [x for _, pts in series for x, _ in pts]
    ys = [y for _, pts in series for _, y in pts]
    x0, x1 = min(xs), max(xs)
    if x1 - x0 < 1e-9:
        x0, x1 = x0 - 1.0, x1 + 1.0
    y0, y1 = 0.0, max(max(ys), 1e-9) * 1.05

    def sx(v):
        return margin + (v - x0) / (x1 - x0) * (w - 2 * margin)

    def sy(v):
        return h - margin - (v - y0) / (y1 - y0) * (h - 2 * margin)

    el = [f'<svg xmlns="http://www.w3.org/2000/svg" width="{w}" '
          f'height="{h}" font-family="monospace" font-size="12">',
          f'<rect width="{w}" height="{h}" fill="white"/>',
          f'<rect x="{margin}" y="{margin}" width="{w - 2 * margin}" '
          f'height="{h - 2 * margin}" fill="none" stroke="#ccc"/>']
    for i in range(5):
        xv = x0 + (x1 - x0) * i / 4
        yv = y0 + (y1 - y0) * i / 4
        el.append(f'<text x="{sx(xv):.1f}" y="{h - margin + 16}" '
                  f'text-anchor="middle">{xv:.0f}</text>')
        el.append(f'<line x1="{margin}" y1="{sy(yv):.1f}" '
                  f'x2="{w - margin}" y2="{sy(yv):.1f}" stroke="#eee"/>')
        el.append(f'<text x="{margin - 6}" y="{sy(yv) + 4:.1f}" '
                  f'text-anchor="end">{yv:.2f}</text>')
    for i, (label, pts) in enumerate(series):
        color = PALETTE[i % len(PALETTE)]
        if len(pts) > 1:
            path = " ".join(f'{sx(x):.1f},{sy(y):.1f}' for x, y in pts)
            el.append(f'<polyline points="{path}" fill="none" '
                      f'stroke="{color}" stroke-width="1.5"/>')
        for x, y in pts:
            el.append(f'<circle cx="{sx(x):.1f}" cy="{sy(y):.1f}" '
                      f'r="3" fill="{color}"/>')
        el.append(f'<text x="{margin + 10}" y="{margin + 18 + 16 * i}" '
                  f'fill="{color}">{label}</text>')
    el.append(f'<text x="{w / 2}" y="{h - 12}" text-anchor="middle">'
              f'context length (tokens)</text>')
    el.append(f'<text x="16" y="{h / 2}" text-anchor="middle" '
              f'transform="rotate(-90 16 {h / 2})">TTFT (ms)</text>')
    el.append(f'<text x="{w / 2}" y="24" text-anchor="middle">TTFT vs '
              f'context length — {meta.get("model", "?")}</text>')
    el.append('</svg>')
    with open(out, "w") as f:
        f.write("\n".join(el) + "\n")
    print(f"wrote {out} (matplotlib unavailable; SVG fallback)")


def plot_matplotlib(doc, series, out):
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    fig, ax = plt.subplots(figsize=(7, 5))
    for label, color, style, pts in series:
        xs, ys = zip(*pts)
        if style == "scatter":
            ax.plot(xs, ys, marker="s", markersize=5, linestyle="--",
                    linewidth=1.0, alpha=0.9, label=label, color=color)
        else:
            ax.plot(xs, ys, marker="o", markersize=3.5,
                    drawstyle="steps-post", label=label, color=color)
    ax.set_xscale("log")
    ax.set_yscale("log")
    ax.set_xlabel("tokens/s/user (normalized)")
    ax.set_ylabel("tokens/s/GPU (normalized)")
    ttl = doc.get("ttl_budget_ms")
    kind = " — predicted vs measured" if doc.get("kind") == "helix-eval" \
        else ""
    ax.set_title(f"Pareto frontier{kind} — {doc.get('model', '?')}"
                 + (f" (TTL budget {ttl} ms)" if ttl else ""))
    ax.grid(True, which="both", alpha=0.3)
    ax.legend()
    fig.tight_layout()
    fig.savefig(out, dpi=150)
    print(f"wrote {out}")


def plot_svg(doc, series, out):
    """Dependency-free fallback: log-log plot as hand-rolled SVG.
    Step series draw the frontier staircase; measured series draw
    square markers joined by a dashed guide line."""
    w, h, margin = 720, 520, 60
    all_pts = [p for _, _, _, pts in series for p in pts]
    lx = [math.log10(max(x, 1e-12)) for x, _ in all_pts]
    ly = [math.log10(max(y, 1e-12)) for _, y in all_pts]
    x0, x1 = min(lx), max(lx)
    y0, y1 = min(ly), max(ly)
    # Degenerate spans (a single point) still need a finite scale.
    if x1 - x0 < 1e-9:
        x0, x1 = x0 - 0.5, x1 + 0.5
    if y1 - y0 < 1e-9:
        y0, y1 = y0 - 0.5, y1 + 0.5
    x1, y1 = x1 + 0.05, y1 + 0.05
    x0, y0 = x0 - 0.05, y0 - 0.05

    def sx(v):
        return margin + (math.log10(max(v, 1e-12)) - x0) / (x1 - x0) \
            * (w - 2 * margin)

    def sy(v):
        return h - margin - (math.log10(max(v, 1e-12)) - y0) / (y1 - y0) \
            * (h - 2 * margin)

    el = [f'<svg xmlns="http://www.w3.org/2000/svg" width="{w}" '
          f'height="{h}" font-family="monospace" font-size="12">',
          f'<rect width="{w}" height="{h}" fill="white"/>',
          f'<rect x="{margin}" y="{margin}" width="{w - 2 * margin}" '
          f'height="{h - 2 * margin}" fill="none" stroke="#ccc"/>']
    # Decade gridlines + labels.
    for d in range(math.floor(x0), math.ceil(x1) + 1):
        px = margin + (d - x0) / (x1 - x0) * (w - 2 * margin)
        if margin <= px <= w - margin:
            el.append(f'<line x1="{px:.1f}" y1="{margin}" x2="{px:.1f}" '
                      f'y2="{h - margin}" stroke="#eee"/>')
            el.append(f'<text x="{px:.1f}" y="{h - margin + 16}" '
                      f'text-anchor="middle">1e{d}</text>')
    for d in range(math.floor(y0), math.ceil(y1) + 1):
        py = h - margin - (d - y0) / (y1 - y0) * (h - 2 * margin)
        if margin <= py <= h - margin:
            el.append(f'<line x1="{margin}" y1="{py:.1f}" '
                      f'x2="{w - margin}" y2="{py:.1f}" stroke="#eee"/>')
            el.append(f'<text x="{margin - 6}" y="{py + 4:.1f}" '
                      f'text-anchor="end">1e{d}</text>')
    for i, (label, color, style, pts) in enumerate(series):
        if style == "scatter":
            if len(pts) > 1:
                path = " ".join(f'{sx(x):.1f},{sy(y):.1f}'
                                for x, y in pts)
                el.append(f'<polyline points="{path}" fill="none" '
                          f'stroke="{color}" stroke-width="1.0" '
                          f'stroke-dasharray="5,4"/>')
            for x, y in pts:
                el.append(f'<rect x="{sx(x) - 3:.1f}" '
                          f'y="{sy(y) - 3:.1f}" width="6" height="6" '
                          f'fill="{color}"/>')
        else:
            path = []
            prev = None
            for x, y in pts:
                if prev is not None:
                    path.append(f'{sx(x):.1f},{sy(prev[1]):.1f}')
                path.append(f'{sx(x):.1f},{sy(y):.1f}')
                prev = (x, y)
            el.append(f'<polyline points="{" ".join(path)}" fill="none" '
                      f'stroke="{color}" stroke-width="1.5"/>')
            for x, y in pts:
                el.append(f'<circle cx="{sx(x):.1f}" cy="{sy(y):.1f}" '
                          f'r="2.5" fill="{color}"/>')
        el.append(f'<text x="{margin + 10}" y="{margin + 18 + 16 * i}" '
                  f'fill="{color}">{label}</text>')
    el.append(f'<text x="{w / 2}" y="{h - 12}" text-anchor="middle">'
              f'tokens/s/user (normalized)</text>')
    el.append(f'<text x="16" y="{h / 2}" text-anchor="middle" '
              f'transform="rotate(-90 16 {h / 2})">tokens/s/GPU '
              f'(normalized)</text>')
    el.append(f'<text x="{w / 2}" y="24" text-anchor="middle">Pareto '
              f'frontier — {doc.get("model", "?")}</text>')
    el.append('</svg>')
    with open(out, "w") as f:
        f.write("\n".join(el) + "\n")
    print(f"wrote {out} (matplotlib unavailable; SVG fallback)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("plan",
                    help="JSON from `helix plan --sweep` or `helix eval`")
    ap.add_argument("-o", "--out", default=None)
    ap.add_argument("--model", default=None,
                    help="model to plot from a multi-model eval document "
                         "(default: the first)")
    args = ap.parse_args(argv)
    doc, frontiers = load(args.plan, args.model)
    series = normalized_series(frontiers)
    stem = os.path.splitext(args.plan)[0]
    try:
        import matplotlib  # noqa: F401
        have_mpl = True
    except ImportError:
        have_mpl = False
    if have_mpl:
        out = args.out or stem + ".png"
        if os.path.splitext(out)[1].lstrip(".").lower() not in (
                "png", "svg", "pdf", "jpg", "jpeg", "webp"):
            out += ".png"
        plot_matplotlib(doc, series, out)
    else:
        out = args.out or stem + ".svg"
        if not out.endswith(".svg"):
            out = os.path.splitext(out)[0] + ".svg"
        plot_svg(doc, series, out)
    # Schema-v3 TTFT axis: a second chart next to the frontier plot.
    ttfts = ttft_series(doc)
    if ttfts:
        base, ext = os.path.splitext(out)
        if have_mpl:
            plot_ttft_matplotlib(doc, ttfts, base + ".ttft" + ext)
        else:
            ttft_svg(doc, ttfts, base + ".ttft.svg")


if __name__ == "__main__":
    main()
