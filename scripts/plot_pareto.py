#!/usr/bin/env python3
"""Render the Fig 5/6-style throughput-vs-interactivity frontier from a
``helix plan --sweep`` JSON document.

Usage:
    cargo run --release -- plan --model deepseek-r1 --sweep --out plan.json
    python3 scripts/plot_pareto.py plan.json [-o pareto.png]

With matplotlib installed this writes whatever ``-o``'s suffix says
(default ``<input>.png``); without it, a dependency-free SVG is written
instead (``<input>.svg``). Both axes are normalized to the baseline
frontier's maxima, exactly as the paper reports its results (S3.1).

Stdlib-only by design — matplotlib is optional.
"""

import argparse
import json
import math
import os
import sys

SERIES = [
    # (key in doc["frontiers"], label, color)
    ("baseline", "baseline (best TP/PP/KVP/EP)", "#888888"),
    ("helix", "helix", "#1f6feb"),
]


def load(path):
    with open(path) as f:
        doc = json.load(f)
    frontiers = doc.get("frontiers")
    if not frontiers:
        sys.exit(f"{path}: no \"frontiers\" section — regenerate with "
                 f"`helix plan --sweep`")
    return doc, frontiers


def normalized_series(frontiers):
    base = frontiers.get("baseline") or []
    ni = max((p["tok_s_user"] for p in base), default=1.0) or 1.0
    nt = max((p["tok_s_gpu"] for p in base), default=1.0) or 1.0
    out = []
    for key, label, color in SERIES:
        pts = [(p["tok_s_user"] / ni, p["tok_s_gpu"] / nt)
               for p in frontiers.get(key, [])]
        pts.sort()
        if pts:
            out.append((label, color, pts))
    if not out:
        sys.exit("frontiers are empty — nothing to plot")
    return out


def plot_matplotlib(doc, series, out):
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    fig, ax = plt.subplots(figsize=(7, 5))
    for label, color, pts in series:
        xs, ys = zip(*pts)
        ax.plot(xs, ys, marker="o", markersize=3.5, drawstyle="steps-post",
                label=label, color=color)
    ax.set_xscale("log")
    ax.set_yscale("log")
    ax.set_xlabel("tokens/s/user (normalized to baseline max)")
    ax.set_ylabel("tokens/s/GPU (normalized to baseline max)")
    ttl = doc.get("ttl_budget_ms")
    ax.set_title(f"Pareto frontier — {doc.get('model', '?')}"
                 + (f" (TTL budget {ttl} ms)" if ttl else ""))
    ax.grid(True, which="both", alpha=0.3)
    ax.legend()
    fig.tight_layout()
    fig.savefig(out, dpi=150)
    print(f"wrote {out}")


def plot_svg(doc, series, out):
    """Dependency-free fallback: log-log step plot as hand-rolled SVG."""
    w, h, margin = 720, 520, 60
    all_pts = [p for _, _, pts in series for p in pts]
    lx = [math.log10(max(x, 1e-12)) for x, _ in all_pts]
    ly = [math.log10(max(y, 1e-12)) for _, y in all_pts]
    x0, x1 = min(lx), max(lx)
    y0, y1 = min(ly), max(ly)
    x1, y1 = x1 + 0.05, y1 + 0.05
    x0, y0 = x0 - 0.05, y0 - 0.05

    def sx(v):
        return margin + (math.log10(max(v, 1e-12)) - x0) / (x1 - x0) \
            * (w - 2 * margin)

    def sy(v):
        return h - margin - (math.log10(max(v, 1e-12)) - y0) / (y1 - y0) \
            * (h - 2 * margin)

    el = [f'<svg xmlns="http://www.w3.org/2000/svg" width="{w}" '
          f'height="{h}" font-family="monospace" font-size="12">',
          f'<rect width="{w}" height="{h}" fill="white"/>',
          f'<rect x="{margin}" y="{margin}" width="{w - 2 * margin}" '
          f'height="{h - 2 * margin}" fill="none" stroke="#ccc"/>']
    # Decade gridlines + labels.
    for d in range(math.floor(x0), math.ceil(x1) + 1):
        px = margin + (d - x0) / (x1 - x0) * (w - 2 * margin)
        if margin <= px <= w - margin:
            el.append(f'<line x1="{px:.1f}" y1="{margin}" x2="{px:.1f}" '
                      f'y2="{h - margin}" stroke="#eee"/>')
            el.append(f'<text x="{px:.1f}" y="{h - margin + 16}" '
                      f'text-anchor="middle">1e{d}</text>')
    for d in range(math.floor(y0), math.ceil(y1) + 1):
        py = h - margin - (d - y0) / (y1 - y0) * (h - 2 * margin)
        if margin <= py <= h - margin:
            el.append(f'<line x1="{margin}" y1="{py:.1f}" '
                      f'x2="{w - margin}" y2="{py:.1f}" stroke="#eee"/>')
            el.append(f'<text x="{margin - 6}" y="{py + 4:.1f}" '
                      f'text-anchor="end">1e{d}</text>')
    # Step polylines per series.
    for i, (label, color, pts) in enumerate(series):
        path = []
        prev = None
        for x, y in pts:
            if prev is not None:
                path.append(f'{sx(x):.1f},{sy(prev[1]):.1f}')
            path.append(f'{sx(x):.1f},{sy(y):.1f}')
            prev = (x, y)
        el.append(f'<polyline points="{" ".join(path)}" fill="none" '
                  f'stroke="{color}" stroke-width="1.5"/>')
        for x, y in pts:
            el.append(f'<circle cx="{sx(x):.1f}" cy="{sy(y):.1f}" r="2.5" '
                      f'fill="{color}"/>')
        el.append(f'<text x="{margin + 10}" y="{margin + 18 + 16 * i}" '
                  f'fill="{color}">{label}</text>')
    el.append(f'<text x="{w / 2}" y="{h - 12}" text-anchor="middle">'
              f'tokens/s/user (normalized)</text>')
    el.append(f'<text x="16" y="{h / 2}" text-anchor="middle" '
              f'transform="rotate(-90 16 {h / 2})">tokens/s/GPU '
              f'(normalized)</text>')
    el.append(f'<text x="{w / 2}" y="24" text-anchor="middle">Pareto '
              f'frontier — {doc.get("model", "?")}</text>')
    el.append('</svg>')
    with open(out, "w") as f:
        f.write("\n".join(el) + "\n")
    print(f"wrote {out} (matplotlib unavailable; SVG fallback)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("plan", help="JSON from `helix plan --sweep`")
    ap.add_argument("-o", "--out", default=None)
    args = ap.parse_args()
    doc, frontiers = load(args.plan)
    series = normalized_series(frontiers)
    stem = os.path.splitext(args.plan)[0]
    try:
        import matplotlib  # noqa: F401
        have_mpl = True
    except ImportError:
        have_mpl = False
    if have_mpl:
        out = args.out or stem + ".png"
        if os.path.splitext(out)[1].lstrip(".").lower() not in (
                "png", "svg", "pdf", "jpg", "jpeg", "webp"):
            out += ".png"
        plot_matplotlib(doc, series, out)
    else:
        out = args.out or stem + ".svg"
        if not out.endswith(".svg"):
            out = os.path.splitext(out)[0] + ".svg"
        plot_svg(doc, series, out)


if __name__ == "__main__":
    main()
